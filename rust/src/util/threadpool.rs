//! Thread pools: a queue-based `ThreadPool` for coarse offline fan-out and a
//! persistent, parkable `WorkerPool` for hot-loop scoped work.
//!
//! The offline build has no tokio; the coordinator's parallelism needs are
//! CPU-bound fan-out (evaluate many batches, generate many examples), for
//! which a plain worker pool over an MPMC channel is the right tool anyway.
//! Includes a `scope`-style parallel map used by the eval harness.
//!
//! The decode hot loop has the opposite shape: thousands of tiny ticks per
//! second, each wanting the *same* few threads to chew disjoint ranges of a
//! borrowed output buffer. Boxing `'static` jobs per tick ([`ThreadPool`])
//! or re-spawning OS threads per call (`par_chunks_mut`) are both wrong
//! there, so [`WorkerPool`] keeps its workers parked on a condvar between
//! scopes and runs borrowed closures with a rayon-style pointer-erasure
//! bridge (sound because [`WorkerPool::run`] blocks until every part is
//! done). See DESIGN.md §2.11.

use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed worker pool. Jobs are executed in submission order per the shared
/// queue; `wait_idle` blocks until every submitted job has completed.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, Condvar)>,
}

/// Decrements the pool's pending count on drop, so a panicking job still
/// releases its slot: the panic then surfaces on the worker's stderr (and
/// kills that worker) instead of leaving `wait_idle` deadlocked forever on
/// a count that can no longer reach zero.
struct PendingGuard<'a>(&'a (Mutex<usize>, Condvar));

impl Drop for PendingGuard<'_> {
    fn drop(&mut self) {
        let (lock, cv) = self.0;
        let mut p = lock.lock().unwrap();
        *p -= 1;
        if *p == 0 {
            cv.notify_all();
        }
    }
}

impl ThreadPool {
    /// Create a pool with `n` workers (min 1).
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending: Arc<(Mutex<usize>, Condvar)> = Arc::new((Mutex::new(0), Condvar::new()));
        let mut workers = Vec::with_capacity(n);
        for i in 0..n {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            workers.push(
                thread::Builder::new()
                    .name(format!("nmsparse-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => {
                                let _done = PendingGuard(&pending);
                                job();
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker"),
            );
        }
        ThreadPool {
            tx: Some(tx),
            workers,
            pending,
        }
    }

    /// Pool sized to available parallelism.
    pub fn with_default_size() -> ThreadPool {
        ThreadPool::new(default_threads())
    }

    /// Submit a job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool receiver gone");
    }

    /// Block until all submitted jobs have finished.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close channel; workers exit on recv error
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Default worker count (≥ 1): the `NMSPARSE_THREADS` environment variable
/// when set to a positive integer, otherwise available parallelism. The env
/// override is how tests and CI pin a deterministic thread count without
/// plumbing a flag through every entry point (DESIGN.md §2.11).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("NMSPARSE_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

// ---------------------------------------------------------------------------
// WorkerPool: persistent parked workers for per-tick scoped parallelism.
// ---------------------------------------------------------------------------

/// Type-erased borrowed closure: `data` points at a `F: Fn(usize) + Sync`
/// living in the caller's stack frame and `call` is the monomorphized thunk
/// that reborrows and invokes it. Sound to hand to `'static` worker threads
/// only because [`WorkerPool::run`] does not return until every part has
/// finished (or been drained after a panic), so the pointee outlives every
/// dereference.
#[derive(Clone, Copy)]
struct JobRef {
    data: *const (),
    call: unsafe fn(*const (), usize),
}

// SAFETY: JobRef is only ever published under PoolShared.state's mutex and
// only dereferenced while the owning `run` call is blocked; the closure it
// points at is required to be Sync.
unsafe impl Send for JobRef {}

unsafe fn call_thunk<F: Fn(usize)>(data: *const (), part: usize) {
    (*(data as *const F))(part);
}

struct PoolState {
    job: Option<JobRef>,
    parts: usize,
    next: usize,
    inflight: usize,
    panicked: bool,
    shutdown: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here between scopes; `run` notifies to wake them.
    work: Condvar,
    /// `run` parks here while draining; the last finishing part notifies.
    done: Condvar,
}

/// A persistent pool of parked workers for hot-loop scoped work.
///
/// `WorkerPool::new(t)` spawns `t - 1` OS threads once; the calling thread
/// is the t-th worker. Between scopes the workers sleep on a condvar, so an
/// idle pool costs nothing and a `run` call is one lock + notify (no spawn,
/// no allocation). [`run`](WorkerPool::run) executes a borrowed closure
/// `f(part)` for `part ∈ 0..parts`, caller participating, and returns only
/// when every part is done — which is what makes lending stack borrows to
/// the `'static` workers sound. Scopes must not nest (enforced at runtime):
/// partition the output once, at the top of the kernel.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    workers: Vec<thread::JoinHandle<()>>,
    /// Guards against nested / concurrent scopes on one pool, which would
    /// interleave two jobs' part counters. Atomic (not `Cell`) so the pool
    /// stays `Sync` and part closures may capture `&pool` for inspection.
    in_scope: AtomicBool,
    /// Occupancy metrics, cached at construction so the hot loop pays two
    /// atomic adds, not a registry lookup: scopes dispatched and parts
    /// claimed across them (`parts / (scopes × threads)` = occupancy).
    m_scopes: crate::util::trace::Counter,
    m_parts: crate::util::trace::Counter,
}

impl WorkerPool {
    /// Create a pool with `threads` total workers (min 1). `threads == 1`
    /// spawns nothing: every scope runs inline on the caller.
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                job: None,
                parts: 0,
                next: 0,
                inflight: 0,
                panicked: false,
                shutdown: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads - 1);
        for i in 0..threads - 1 {
            let shared = Arc::clone(&shared);
            workers.push(
                thread::Builder::new()
                    .name(format!("nmsparse-pool-{i}"))
                    .spawn(move || Self::worker_loop(&shared))
                    .expect("spawn pool worker"),
            );
        }
        WorkerPool {
            shared,
            workers,
            in_scope: AtomicBool::new(false),
            m_scopes: crate::util::trace::counter("pool.scopes"),
            m_parts: crate::util::trace::counter("pool.parts"),
        }
    }

    /// Total workers, caller included. Kernels use this to pick a part count.
    pub fn threads(&self) -> usize {
        self.workers.len() + 1
    }

    fn worker_loop(shared: &PoolShared) {
        let mut st = shared.state.lock().unwrap();
        loop {
            let job = loop {
                if st.shutdown {
                    return;
                }
                if let Some(job) = st.job {
                    if st.next < st.parts {
                        break job;
                    }
                }
                st = shared.work.wait(st).unwrap();
            };
            let part = st.next;
            st.next += 1;
            st.inflight += 1;
            drop(st);
            let ok = catch_unwind(AssertUnwindSafe(|| unsafe { (job.call)(job.data, part) }));
            st = shared.state.lock().unwrap();
            st.inflight -= 1;
            if ok.is_err() {
                st.panicked = true;
            }
            if st.next >= st.parts && st.inflight == 0 {
                shared.done.notify_all();
            }
        }
    }

    /// Run `f(part)` for every `part` in `0..parts` across the pool, caller
    /// participating, and return once all parts have completed. Parts are
    /// claimed dynamically (work-stealing by counter), so callers should
    /// make parts ≈ [`threads`](WorkerPool::threads) with balanced cost.
    ///
    /// `f` only borrows (no `'static` bound): sound because this call blocks
    /// until the last part finishes, draining stragglers even if a part
    /// panics (the panic is then propagated to the caller). Panics if
    /// called from inside another scope on the same pool.
    pub fn run<F>(&self, parts: usize, f: F)
    where
        F: Fn(usize) + Sync,
    {
        if parts == 0 {
            return;
        }
        self.m_scopes.inc();
        self.m_parts.add(parts as u64);
        assert!(
            !self.in_scope.swap(true, Ordering::SeqCst),
            "nested WorkerPool scope: partition once at the top of the kernel"
        );
        // Reset `in_scope` even when unwinding, so a caught panic leaves
        // the pool reusable.
        struct ScopeGuard<'a>(&'a AtomicBool);
        impl Drop for ScopeGuard<'_> {
            fn drop(&mut self) {
                self.0.store(false, Ordering::SeqCst);
            }
        }
        let _scope = ScopeGuard(&self.in_scope);

        if self.workers.is_empty() || parts == 1 {
            for part in 0..parts {
                f(part);
            }
            return;
        }

        let job = JobRef {
            data: &f as *const F as *const (),
            call: call_thunk::<F>,
        };
        let mut st = self.shared.state.lock().unwrap();
        st.job = Some(job);
        st.parts = parts;
        st.next = 0;
        st.panicked = false;
        drop(st);
        self.shared.work.notify_all();

        // The caller claims parts like any worker (no idle spin-up gap).
        let mut caller_panic = None;
        loop {
            let mut st = self.shared.state.lock().unwrap();
            if st.next >= st.parts {
                // Out of parts: drain stragglers before releasing borrows.
                while st.inflight > 0 {
                    st = self.shared.done.wait(st).unwrap();
                }
                st.job = None;
                st.parts = 0;
                st.next = 0;
                let worker_panicked = st.panicked;
                st.panicked = false;
                drop(st);
                if let Some(payload) = caller_panic {
                    resume_unwind(payload);
                }
                if worker_panicked {
                    panic!("WorkerPool part panicked on a pool thread (see stderr)");
                }
                return;
            }
            let part = st.next;
            st.next += 1;
            st.inflight += 1;
            drop(st);
            let ok = catch_unwind(AssertUnwindSafe(|| f(part)));
            let mut st = self.shared.state.lock().unwrap();
            st.inflight -= 1;
            if let Err(payload) = ok {
                // Remember the first caller-side panic but keep claiming:
                // stopping early would strand unclaimed parts and deadlock
                // the drain below. Bump `next` past the end to stop new
                // claims instead.
                if caller_panic.is_none() {
                    caller_panic = Some(payload);
                }
                st.next = st.parts;
            }
            drop(st);
        }
    }

    /// Partition `0..n` into at most [`threads`](WorkerPool::threads)
    /// contiguous ranges and run `f(start, end)` for each across the pool.
    /// The common entry point for the row-partitioned kernels: ranges are
    /// disjoint by construction, so each worker owns its output rows.
    pub fn run_ranges<F>(&self, n: usize, f: F)
    where
        F: Fn(usize, usize) + Sync,
    {
        if n == 0 {
            return;
        }
        let parts = self.threads().min(n);
        let per = (n + parts - 1) / parts;
        self.run(parts, |p| {
            let lo = p * per;
            let hi = ((p + 1) * per).min(n);
            if lo < hi {
                f(lo, hi);
            }
        });
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for w in self.workers.drain(..) {
            w.join().ok();
        }
    }
}

/// Shared-mutable view over a slice whose writes are *disjoint by caller
/// contract*. The row-partitioned kernels write strided (lane-major) output
/// elements from several workers at once — disjoint index sets, but not
/// contiguous spans, so `split_at_mut` cannot express them. Each `write` /
/// `slice_mut` is `unsafe`: the caller asserts no two concurrent calls
/// touch overlapping indices and the pointee outlives the scope (both hold
/// for [`WorkerPool::run`] over disjoint ranges).
pub struct DisjointSliceMut<'a, T> {
    ptr: *mut T,
    len: usize,
    _life: PhantomData<&'a mut [T]>,
}

// SAFETY: sharing the raw pointer across workers is sound because every
// dereference site is itself unsafe and contracts disjointness.
unsafe impl<T: Send> Send for DisjointSliceMut<'_, T> {}
unsafe impl<T: Send> Sync for DisjointSliceMut<'_, T> {}

impl<'a, T> DisjointSliceMut<'a, T> {
    pub fn new(slice: &'a mut [T]) -> DisjointSliceMut<'a, T> {
        DisjointSliceMut {
            ptr: slice.as_mut_ptr(),
            len: slice.len(),
            _life: PhantomData,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Write one element. SAFETY: `i < len` and no concurrent access to `i`.
    #[inline]
    pub unsafe fn write(&self, i: usize, v: T) {
        debug_assert!(i < self.len);
        *self.ptr.add(i) = v;
    }

    /// Reborrow a subrange as `&mut [T]`. SAFETY: `start + len <= self.len`
    /// and no concurrent access to any index in the range.
    #[inline]
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Parallel in-place map over disjoint chunks of `data`: `f(chunk_index,
/// chunk)` is called for every `chunk_len`-sized chunk (the last may be
/// shorter), spread across up to `threads` scoped workers. Chunks are
/// assigned contiguously so each worker touches one memory span; the call
/// blocks until every chunk is done. Used by the fused sparsification
/// pipeline's row-parallel batch driver.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk_len > 0, "chunk_len must be positive");
    if data.is_empty() {
        return;
    }
    let n_chunks = (data.len() + chunk_len - 1) / chunk_len;
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        for (i, chunk) in data.chunks_mut(chunk_len).enumerate() {
            f(i, chunk);
        }
        return;
    }
    let chunks_per_worker = (n_chunks + threads - 1) / threads;
    thread::scope(|scope| {
        let mut rest = data;
        let mut first_chunk = 0usize;
        while !rest.is_empty() {
            let take = (chunks_per_worker * chunk_len).min(rest.len());
            let (span, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let base = first_chunk;
            scope.spawn(move || {
                for (i, chunk) in span.chunks_mut(chunk_len).enumerate() {
                    f(base + i, chunk);
                }
            });
            first_chunk += chunks_per_worker;
        }
    });
}

/// Lockstep dual-slice variant of [`par_chunks_mut`]: splits `a` into
/// `a_chunk`-sized chunks and `b` into `b_chunk`-sized chunks (same chunk
/// count required — the last chunk of each may be shorter) and calls
/// `f(chunk_index, a_chunk, b_chunk)` for each pair across up to `threads`
/// scoped workers. Used by the packed-stream emitter, whose kept-values and
/// metadata-words outputs are two parallel row-blocked arrays.
pub fn par_chunks2_mut<A, B, F>(
    a: &mut [A],
    a_chunk: usize,
    b: &mut [B],
    b_chunk: usize,
    threads: usize,
    f: F,
) where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(a_chunk > 0 && b_chunk > 0, "chunk lengths must be positive");
    let n_chunks = (a.len() + a_chunk - 1) / a_chunk;
    assert_eq!(
        n_chunks,
        (b.len() + b_chunk - 1) / b_chunk,
        "slices disagree on chunk count"
    );
    if a.is_empty() && b.is_empty() {
        return;
    }
    let threads = threads.max(1).min(n_chunks);
    if threads == 1 {
        for (i, (ca, cb)) in a.chunks_mut(a_chunk).zip(b.chunks_mut(b_chunk)).enumerate() {
            f(i, ca, cb);
        }
        return;
    }
    let chunks_per_worker = (n_chunks + threads - 1) / threads;
    thread::scope(|scope| {
        let mut rest_a = a;
        let mut rest_b = b;
        let mut first_chunk = 0usize;
        while !rest_a.is_empty() || !rest_b.is_empty() {
            let take_a = (chunks_per_worker * a_chunk).min(rest_a.len());
            let take_b = (chunks_per_worker * b_chunk).min(rest_b.len());
            let (span_a, tail_a) = rest_a.split_at_mut(take_a);
            let (span_b, tail_b) = rest_b.split_at_mut(take_b);
            rest_a = tail_a;
            rest_b = tail_b;
            let f = &f;
            let base = first_chunk;
            scope.spawn(move || {
                for (i, (ca, cb)) in span_a
                    .chunks_mut(a_chunk)
                    .zip(span_b.chunks_mut(b_chunk))
                    .enumerate()
                {
                    f(base + i, ca, cb);
                }
            });
            first_chunk += chunks_per_worker;
        }
    });
}

/// Parallel map: applies `f` to every item, preserving order, using `threads`
/// workers via scoped threads (no 'static bound on inputs).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len().max(1));
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    let slots = Mutex::new(&mut out);
    thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                // Each index is written exactly once; the mutex only guards
                // the Vec header, contention is negligible vs work done.
                let mut guard = slots.lock().unwrap();
                guard[i] = Some(r);
            });
        }
    });
    out.into_iter().map(|r| r.expect("slot filled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn panicking_job_does_not_deadlock_wait_idle() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicU64::new(0));
        pool.execute(|| panic!("job panic (expected in test output)"));
        for _ in 0..8 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // Before the drop-guard fix this hung forever: the panicking job
        // never decremented `pending`, so the count could not reach zero.
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn default_threads_honors_env_override() {
        // Serialize against other tests reading the env by doing all the
        // mutation in one test; edition-2021 `set_var` is a safe fn.
        std::env::set_var("NMSPARSE_THREADS", "3");
        assert_eq!(default_threads(), 3);
        std::env::set_var("NMSPARSE_THREADS", " 5 ");
        assert_eq!(default_threads(), 5, "override is trimmed before parse");
        std::env::set_var("NMSPARSE_THREADS", "0");
        assert!(default_threads() >= 1, "zero falls back to parallelism");
        std::env::set_var("NMSPARSE_THREADS", "not-a-number");
        assert!(default_threads() >= 1, "junk falls back to parallelism");
        std::env::remove_var("NMSPARSE_THREADS");
        assert!(default_threads() >= 1);
    }

    #[test]
    fn worker_pool_covers_all_parts_and_is_reusable() {
        let pool = WorkerPool::new(4);
        assert_eq!(pool.threads(), 4);
        let hits: Vec<AtomicU64> = (0..23).map(|_| AtomicU64::new(0)).collect();
        // Many scopes back-to-back: parks and wakes must not lose parts.
        for _ in 0..50 {
            pool.run(hits.len(), |p| {
                hits[p].fetch_add(1, Ordering::SeqCst);
            });
        }
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 50, "part {i}");
        }
    }

    #[test]
    fn worker_pool_single_thread_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0u64; 9];
        let shared = DisjointSliceMut::new(&mut out);
        pool.run(9, |p| unsafe { shared.write(p, p as u64 + 1) });
        assert_eq!(out, (1..=9).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_pool_run_ranges_partitions_disjointly() {
        let pool = WorkerPool::new(3);
        // 10 rows of 4 strided columns: lane-major writes, disjoint rows.
        let (rows, cols) = (10usize, 4usize);
        let mut out = vec![0u64; rows * cols];
        let shared = DisjointSliceMut::new(&mut out);
        pool.run_ranges(rows, |lo, hi| {
            for r in lo..hi {
                for c in 0..cols {
                    // SAFETY: row ranges are disjoint across parts.
                    unsafe { shared.write(c * rows + r, (r * cols + c) as u64 + 1) };
                }
            }
        });
        for r in 0..rows {
            for c in 0..cols {
                assert_eq!(out[c * rows + r], (r * cols + c) as u64 + 1);
            }
        }
    }

    #[test]
    #[should_panic(expected = "panicked")]
    fn worker_pool_part_panic_propagates() {
        let pool = WorkerPool::new(2);
        let data = vec![1u64; 8];
        pool.run(8, |p| {
            assert!(data[p] == 1);
            if p == 3 {
                panic!("part panicked deliberately");
            }
        });
    }

    #[test]
    fn worker_pool_survives_a_panicked_scope() {
        let pool = WorkerPool::new(2);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(4, |p| {
                if p % 2 == 0 {
                    panic!("scope poisoned (expected in test output)");
                }
            });
        }));
        assert!(r.is_err());
        // The drain completed and the flags were reset: the pool still works.
        let counter = AtomicU64::new(0);
        pool.run(7, |_| {
            counter.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(counter.load(Ordering::SeqCst), 7);
    }

    #[test]
    #[should_panic(expected = "nested WorkerPool scope")]
    fn worker_pool_rejects_nested_scopes() {
        let pool = WorkerPool::new(2);
        // parts == 1 keeps the outer closure on the caller thread, so the
        // nested `run` below deterministically trips the in_scope check.
        pool.run(1, |_| {
            pool.run(1, |_| {});
        });
    }

    #[test]
    fn worker_pool_drop_joins_cleanly() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = WorkerPool::new(3);
            let c = Arc::clone(&counter);
            pool.run(6, |_| {
                c.fetch_add(1, Ordering::SeqCst);
            });
        } // drop: workers must wake from their park and join
        assert_eq!(counter.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, 8, |x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunks_mut_covers_all_chunks() {
        let mut data: Vec<u64> = vec![0; 103]; // deliberately not a multiple
        par_chunks_mut(&mut data, 10, 4, |ci, chunk| {
            for v in chunk.iter_mut() {
                *v = ci as u64 + 1;
            }
        });
        // Every element written, with its chunk's 1-based index.
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, (i / 10) as u64 + 1, "element {i}");
        }
    }

    #[test]
    fn par_chunks_mut_single_thread_and_empty() {
        let mut data: Vec<u8> = vec![0; 7];
        par_chunks_mut(&mut data, 3, 1, |_ci, chunk| {
            for v in chunk.iter_mut() {
                *v += 1;
            }
        });
        assert_eq!(data, vec![1; 7]);
        let mut empty: Vec<u8> = vec![];
        par_chunks_mut(&mut empty, 3, 4, |_, _| panic!("no chunks expected"));
    }

    #[test]
    fn par_chunks2_mut_lockstep_coverage() {
        // 7 chunks of (3, 2): last chunk of each is short.
        let mut a: Vec<u64> = vec![0; 20];
        let mut b: Vec<u64> = vec![0; 13];
        par_chunks2_mut(&mut a, 3, &mut b, 2, 4, |ci, ca, cb| {
            for v in ca.iter_mut() {
                *v = ci as u64 + 1;
            }
            for v in cb.iter_mut() {
                *v = (ci as u64 + 1) * 100;
            }
        });
        for (i, v) in a.iter().enumerate() {
            assert_eq!(*v, (i / 3) as u64 + 1, "a[{i}]");
        }
        for (i, v) in b.iter().enumerate() {
            assert_eq!(*v, ((i / 2) as u64 + 1) * 100, "b[{i}]");
        }
        // Single-thread path and empty inputs.
        let mut a: Vec<u8> = vec![0; 4];
        let mut b: Vec<u8> = vec![0; 2];
        par_chunks2_mut(&mut a, 2, &mut b, 1, 1, |_ci, ca, cb| {
            ca.iter_mut().for_each(|v| *v += 1);
            cb.iter_mut().for_each(|v| *v += 1);
        });
        assert_eq!(a, vec![1; 4]);
        assert_eq!(b, vec![1; 2]);
        let mut ea: Vec<u8> = vec![];
        let mut eb: Vec<u8> = vec![];
        par_chunks2_mut(&mut ea, 1, &mut eb, 1, 4, |_, _, _| panic!("no chunks"));
    }

    #[test]
    #[should_panic(expected = "chunk count")]
    fn par_chunks2_mut_rejects_mismatched_chunk_counts() {
        let mut a: Vec<u8> = vec![0; 10];
        let mut b: Vec<u8> = vec![0; 2];
        par_chunks2_mut(&mut a, 2, &mut b, 1, 2, |_, _, _| {});
    }

    #[test]
    fn default_threads_positive() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn par_map_empty() {
        let items: Vec<usize> = vec![];
        let out = par_map(&items, 4, |x| *x);
        assert!(out.is_empty());
    }

    #[test]
    fn pool_drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                std::thread::sleep(std::time::Duration::from_millis(1));
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }
}
