//! Property suite for the compressed-domain path (no artifacts needed):
//!
//! 1. pack → unpack → dense is bit-identical to the fused `Sparsifier`'s
//!    dense output for every paper pattern (2:4, 4:8, 8:16, 16:32 and
//!    unstructured top-k), including tie-heavy rows;
//! 2. the parallel packed emitter equals the serial one at any thread
//!    count, and the packed GEMV agrees with the dense GEMV;
//! 3. LUT-combinadic ≡ loop-combinadic — every rank at 2:4, sampled ranks
//!    at 8:16 and 16:32;
//! 4. the word-level codec's byte streams are bit-identical to the seed
//!    per-bit path, and corrupted IndexList streams are rejected.
//!
//! `tools/ci.sh` runs this file as the packed smoke
//! (`cargo test -q --test packed_roundtrip`).

use nmsparse::metadata::{
    decode_combinadic, encode_combinadic, mask_to_word, CombinadicLut, MaskCodec,
};
use nmsparse::sparsity::{paper_patterns, PackedNM, Pattern, Scratch, Sparsifier};
use nmsparse::util::miniprop::{forall_simple, gen_activations, Config};
use nmsparse::util::prng::Rng;
use nmsparse::util::tensor::Tensor;

#[test]
fn pack_unpack_bit_identical_to_sparsifier_all_paper_patterns() {
    let cfg = Config::default();
    let patterns = paper_patterns();
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = *rng.choose(&patterns);
            let rows = rng.range(1, 6);
            // All paper patterns have M | 32; gen_activations seeds exact
            // ±1.0 ties and zeros (the adversarial tie-heavy distribution).
            let h = 32 * rng.range(1, 5);
            (gen_activations(rng, rows * h), rows, h, pattern)
        },
        |(xs, rows, h, pattern)| {
            let x = Tensor::from_vec(&[*rows, *h], xs.clone());
            let sp = Sparsifier::new(*pattern);
            let mut scratch = Scratch::new();
            let mut packed = PackedNM::new(*pattern, *h);
            sp.pack(&x, &mut packed, &mut scratch);
            let mut dense = x.clone();
            sp.sparsify(&mut dense, &mut scratch);
            let mut decoded = Tensor::zeros(&[*rows, *h]);
            packed.decode_into(&mut decoded, 1);
            decoded
                .data
                .iter()
                .zip(&dense.data)
                .all(|(a, b)| a.to_bits() == b.to_bits())
        },
    );
}

#[test]
fn pack_batch_equals_serial_pack_any_thread_count() {
    let cfg = Config { cases: 48, ..Config::default() };
    let patterns = paper_patterns();
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = *rng.choose(&patterns);
            let rows = rng.range(1, 20);
            let h = 32 * rng.range(1, 4);
            let threads = *rng.choose(&[1usize, 2, 3, 7, 16]);
            (gen_activations(rng, rows * h), rows, h, pattern, threads)
        },
        |(xs, rows, h, pattern, threads)| {
            let x = Tensor::from_vec(&[*rows, *h], xs.clone());
            let sp = Sparsifier::new(*pattern);
            let mut scratch = Scratch::new();
            let mut serial = PackedNM::new(*pattern, *h);
            sp.pack(&x, &mut serial, &mut scratch);
            let mut par = PackedNM::new(*pattern, *h);
            sp.pack_batch(&x, &mut par, *threads);
            par == serial
        },
    );
}

#[test]
fn packed_gemv_agrees_with_dense_gemv() {
    let cfg = Config { cases: 48, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let rows = rng.range(1, 12);
            let h = 32 * rng.range(1, 4);
            let xs = gen_activations(rng, rows * h);
            let v = gen_activations(rng, h);
            (xs, v, rows, h)
        },
        |(xs, v, rows, h)| {
            let x = Tensor::from_vec(&[*rows, *h], xs.clone());
            let sp = Sparsifier::new(Pattern::NM { n: 8, m: 16 });
            let mut scratch = Scratch::new();
            let mut packed = PackedNM::new(sp.pattern(), *h);
            sp.pack(&x, &mut packed, &mut scratch);
            let mut dense = x.clone();
            sp.sparsify(&mut dense, &mut scratch);
            let mut out = vec![0.0f32; *rows];
            packed.matvec_into(v, &mut out, 3);
            (0..*rows).all(|r| {
                let expect: f32 = dense.row(r).iter().zip(v).map(|(a, b)| a * b).sum();
                (out[r] - expect).abs() <= 1e-3 * expect.abs().max(1.0)
            })
        },
    );
}

#[test]
fn lut_combinadic_equals_loop_every_rank_2_4() {
    let lut = CombinadicLut::new(2, 4);
    assert_eq!(lut.total(), 6);
    for rank in 0..6u64 {
        let mask = decode_combinadic(rank as u128, 2, 4).unwrap();
        let word = mask_to_word(&mask);
        assert_eq!(lut.decode_word(rank).unwrap(), word);
        assert_eq!(lut.encode_word(word) as u128, encode_combinadic(&mask));
        assert_eq!(lut.encode_word(word), rank);
    }
}

#[test]
fn lut_combinadic_equals_loop_sampled_large_patterns() {
    let cfg = Config { cases: 256, ..Config::default() };
    let luts = [CombinadicLut::new(8, 16), CombinadicLut::new(16, 32)];
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let which = rng.below(2);
            (which, rng.next_u64() % luts[which].total())
        },
        |&(which, rank)| {
            let lut = &luts[which];
            let (n, m) = if which == 0 { (8, 16) } else { (16, 32) };
            let mask = decode_combinadic(rank as u128, n, m).unwrap();
            let word = mask_to_word(&mask);
            lut.decode_word(rank).unwrap() == word
                && lut.encode_word(word) == rank
                && lut.encode_word(word) as u128 == encode_combinadic(&mask)
        },
    );
}

#[test]
fn word_codec_streams_equal_reference_streams() {
    let cfg = Config { cases: 64, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let (n, m) = *rng.choose(&[(2usize, 4usize), (4, 8), (8, 16), (16, 32)]);
            let count = rng.range(1, 30);
            let masks: Vec<Vec<bool>> = (0..count)
                .map(|_| {
                    let idx = rng.sample_indices(m, n);
                    let mut mk = vec![false; m];
                    for i in idx {
                        mk[i] = true;
                    }
                    mk
                })
                .collect();
            (masks, n, m, rng.below(3))
        },
        |(masks, n, m, codec_i)| {
            let codec =
                [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic][*codec_i];
            let (ref_bytes, ref_bits) = codec.reference_encode_blocks(masks, *n, *m);
            let (bytes, bits) = codec.encode_blocks(masks, *n, *m);
            bytes == ref_bytes
                && bits == ref_bits
                && codec.decode_blocks(&bytes, masks.len(), *n, *m).unwrap() == *masks
        },
    );
}

#[test]
fn corrupted_index_list_rejected() {
    // Encode [0, 2] then corrupt into [0, 0]: 2-bit indices at 2:4, so the
    // block byte 0b00_1000 -> 0b00_0000.
    let masks = vec![vec![true, false, true, false]];
    let (mut bytes, _) = MaskCodec::IndexList.encode_blocks(&masks, 2, 4);
    assert_eq!(
        MaskCodec::IndexList.decode_blocks(&bytes, 1, 2, 4).unwrap(),
        masks
    );
    bytes[0] &= 0b0011; // second index 2 -> 0, duplicating the first
    let err = MaskCodec::IndexList
        .decode_blocks(&bytes, 1, 2, 4)
        .unwrap_err()
        .to_string();
    assert!(err.contains("duplicate index"), "{err}");
}

#[test]
fn packed_fidelity_matches_dense_difference() {
    let cfg = Config { cases: 64, ..Config::default() };
    let patterns = paper_patterns();
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = *rng.choose(&patterns);
            let rows = rng.range(1, 8);
            let h = 32 * rng.range(1, 4);
            (gen_activations(rng, rows * h), rows, h, pattern)
        },
        |(xs, rows, h, pattern)| {
            let x = Tensor::from_vec(&[*rows, *h], xs.clone());
            let sp = Sparsifier::new(*pattern);
            let mut scratch = Scratch::new();
            let mut packed = PackedNM::new(*pattern, *h);
            sp.pack(&x, &mut packed, &mut scratch);
            let mut dense = x.clone();
            sp.sparsify(&mut dense, &mut scratch);
            let denom = x.l2().max(1e-12);
            let diff = x
                .data
                .iter()
                .zip(&dense.data)
                .map(|(a, b)| ((a - b) as f64).powi(2))
                .sum::<f64>()
                .sqrt();
            packed.fidelity_error_vs(&x).to_bits() == (diff / denom).to_bits()
        },
    );
}
