//! Shape-claim regression over generated results: replays the paper's
//! qualitative claims against the JSON tables `nmsparse table all` wrote to
//! `results/`. Skips when results are absent; `make artifacts && nmsparse
//! table all` refreshes them. This keeps EXPERIMENTS.md honest — if a code
//! change silently breaks an ordering, this test catches it without
//! rerunning the evals.

use nmsparse::util::json::{self, Json};
use std::path::Path;

fn load(id: &str) -> Option<Json> {
    let path = format!("results/{id}.json");
    if !Path::new(&path).exists() {
        eprintln!("{path} missing — run `nmsparse table all`; skipping");
        return None;
    }
    Some(json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

/// Parse a "12.34%" cell.
fn pct(cell: &str) -> f64 {
    cell.trim_end_matches('%').parse().unwrap()
}

/// Find a row by predicate on its cells; return the cells.
fn rows(t: &Json) -> Vec<Vec<String>> {
    t.req("rows")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| {
            r.as_arr()
                .unwrap()
                .iter()
                .map(|c| c.as_str().unwrap_or("").to_string())
                .collect()
        })
        .collect()
}

#[test]
fn fig2_pattern_ordering_is_monotone() {
    let Some(t) = load("fig2") else { return };
    let rs = rows(&t);
    let drop = |pat: &str| -> f64 {
        pct(&rs.iter().find(|r| r[0] == pat).unwrap()[5])
    };
    // The paper's central figure: flexibility strictly helps.
    assert!(drop("2:4") > drop("4:8"), "2:4 vs 4:8");
    assert!(drop("4:8") > drop("8:16"), "4:8 vs 8:16");
    assert!(drop("8:16") > drop("16:32"), "8:16 vs 16:32");
    assert!(drop("16:32") >= drop("u50") - 1.0, "16:32 approaches u50");
    assert!(drop("u70") > 15.0, "u70 collapses");
    // Abstract's headline: large patterns retain multiple x the accuracy.
    assert!(drop("2:4") / drop("16:32").max(0.1) > 2.0);
}

#[test]
fn fig1_act_beats_wt_at_moderate_sparsity() {
    let Some(t) = load("fig1") else { return };
    let rs = rows(&t);
    let drop = |sp: &str, target: &str| -> f64 {
        pct(
            &rs.iter()
                .find(|r| r[0] == sp && r[1] == target)
                .unwrap_or_else(|| panic!("{sp}/{target}"))[7],
        )
    };
    assert!(drop("50%", "act") <= drop("50%", "wt") + 0.5);
    assert!(drop("70%", "act") < drop("70%", "wt"));
    // 90%: both near collapse (>40% drop).
    assert!(drop("90%", "act") > 40.0 && drop("90%", "wt") > 40.0);
}

#[test]
fn table2_every_method_improves_with_block_size() {
    let Some(t) = load("table2") else { return };
    let rs = rows(&t);
    let drop = |pat: &str, m: &str| -> Option<f64> {
        rs.iter()
            .find(|r| r[1] == pat && r[2] == m && r[0] == "Act")
            .map(|r| pct(&r[3]))
    };
    let mut better = 0;
    let mut total = 0;
    for m in [
        "ACT", "CLACT", "Amber-Pruner", "VAR", "D-PTS", "S-PTS", "L-PTS",
        "R-Sparse(64)", "R-Sparse(128)",
    ] {
        if let (Some(a), Some(b)) = (drop("2:4", m), drop("8:16", m)) {
            total += 1;
            if b <= a {
                better += 1;
            }
        }
    }
    assert!(total >= 8, "expected the full method grid, got {total}");
    assert!(
        better == total,
        "8:16 should beat 2:4 for every method ({better}/{total})"
    );
}

#[test]
fn table3_generative_degrades_more_than_qa() {
    let (Some(t3), Some(t2)) = (load("table3"), load("table2")) else {
        return;
    };
    let r3 = rows(&t3);
    let orig_ps: f64 = r3
        .iter()
        .find(|r| r[0] == "ORIG")
        .unwrap()[1]
        .split('/')
        .next()
        .unwrap()
        .parse()
        .unwrap();
    let spts_816: f64 = r3
        .iter()
        .find(|r| r[0] == "S-PTS")
        .unwrap()[2]
        .split('/')
        .next()
        .unwrap()
        .parse()
        .unwrap();
    let ifeval_rel_drop = (orig_ps - spts_816) / orig_ps * 100.0;
    let r2 = rows(&t2);
    let qa_drop = pct(
        &r2.iter()
            .find(|r| r[1] == "8:16" && r[2] == "S-PTS")
            .unwrap()[3],
    );
    assert!(
        ifeval_rel_drop > qa_drop,
        "IFEval relative drop ({ifeval_rel_drop:.1}%) should exceed QA drop ({qa_drop:.1}%)"
    );
}

#[test]
fn table8_no_combination_beats_best_single() {
    let Some(t) = load("table8") else { return };
    let rs = rows(&t);
    let combos: Vec<f64> = rs
        .iter()
        .filter(|r| r[0].contains('+') && !r[0].starts_with("(single)"))
        .map(|r| pct(&r[1]))
        .collect();
    let singles: Vec<f64> = rs
        .iter()
        .filter(|r| r[0].starts_with("(single)"))
        .map(|r| pct(&r[1]))
        .collect();
    assert!(!combos.is_empty() && !singles.is_empty());
    let best_single = singles.iter().cloned().fold(f64::INFINITY, f64::min);
    let best_combo = combos.iter().cloned().fold(f64::INFINITY, f64::min);
    // Paper §3.6 (with a small tolerance for eval noise).
    assert!(
        best_combo >= best_single - 1.0,
        "combination {best_combo:.2}% should not decisively beat best single {best_single:.2}%"
    );
}

#[test]
fn table5_layer_subsets_reduce_drop() {
    let Some(t) = load("table5") else { return };
    let rs = rows(&t);
    for method in ["LS+L-PTS", "LS+L-PTS+VAR"] {
        let all = pct(&rs.iter().find(|r| r[0] == method && r[1] == "all").unwrap()[4]);
        for subset in ["key,out,gate,down", "key,value,gate,down"] {
            let sub = pct(&rs.iter().find(|r| r[0] == method && r[1] == subset).unwrap()[4]);
            assert!(sub < all, "{method}/{subset}: {sub} !< {all}");
        }
    }
}

#[test]
fn table14_quant_lossless_and_sparsity_close() {
    let Some(t) = load("table14") else { return };
    let rs = rows(&t);
    let drop = |prefix: &str| -> f64 {
        pct(&rs.iter().find(|r| r[0].starts_with(prefix)).unwrap()[5])
    };
    assert!(drop("int8").abs() < 2.0, "int8 should be ~lossless");
    assert!(drop("50% unstruct + S-PTS") < 8.0);
    assert!(drop("8:16 + D-PTS") < 8.0);
}
