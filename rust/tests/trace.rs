//! Global-state pinning for the tracing substrate (`util::trace`) —
//! separate process from the lib tests so enabling Metrics/Full here
//! cannot race `loadgen::run`'s own `ensure`/`reset` calls. Within this
//! binary a local mutex serializes the tests, since they all mutate one
//! process-wide recorder.
//!
//! Pins the ISSUE's four trace properties: ring wrap with drop-oldest
//! accounting, nested begin/end pairing under thread fan-out, the
//! zero-allocation disabled mode, and bitwise decode identity with
//! tracing on vs. off.

use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::launcher::loadgen::{self, BackendChoice, LoadgenConfig, Mode};
use nmsparse::sparsity::Pattern;
use nmsparse::util::trace::{self, Phase, TraceLevel, RING_CAP};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

/// Serialize tests touching the process-wide recorder; recover from a
/// poisoned lock so one failure doesn't cascade into the rest.
fn serial() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Start a test from a clean recorder at `level`.
fn begin(level: TraceLevel) {
    trace::set_level(TraceLevel::Off);
    trace::reset();
    let _ = trace::take_spans();
    trace::set_level(level);
}

/// Return the recorder to the quiet default.
fn end() {
    trace::set_level(TraceLevel::Off);
    trace::reset();
    let _ = trace::take_spans();
}

#[test]
fn ring_wraps_drop_oldest_and_accounts_drops() {
    let _g = serial();
    begin(TraceLevel::Full);
    let extra = 500u64;
    let n = RING_CAP as u64 + extra;
    for i in 0..n {
        trace::record_duration(Phase::Pack, i + 1, Duration::from_nanos(10));
    }
    // Aggregates see every span; the ring only keeps the newest RING_CAP.
    let snap = trace::snapshot();
    let pack = snap
        .phases
        .iter()
        .find(|a| a.phase == Phase::Pack)
        .expect("pack phase aggregated");
    assert_eq!(pack.count, n, "aggregate counts all spans, even evicted ones");
    assert_eq!(snap.dropped_spans, extra, "one drop per wrap past capacity");
    let spans = trace::take_spans();
    assert_eq!(spans.len(), RING_CAP, "ring retains exactly RING_CAP events");
    for (j, s) in spans.iter().enumerate() {
        assert_eq!(
            s.id,
            extra + 1 + j as u64,
            "drain must be the newest RING_CAP spans, oldest-first"
        );
    }
    end();
}

#[test]
fn nested_spans_pair_under_thread_fanout() {
    let _g = serial();
    begin(TraceLevel::Full);
    const THREADS: usize = 4;
    const TICKS: u64 = 50;
    const CHILDREN: u64 = 3;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            s.spawn(|| {
                for t in 0..TICKS {
                    let tick = trace::span_id(Phase::TickBuild, t + 1);
                    for c in 0..CHILDREN {
                        let child = trace::span_id(Phase::Attention, c + 1);
                        std::hint::black_box(c);
                        drop(child);
                    }
                    drop(tick);
                }
            });
        }
    });
    // Scope join killed the workers, whose TLS drop flushed their sinks.
    let spans = trace::take_spans();
    assert_eq!(spans.len(), THREADS * (TICKS * (1 + CHILDREN)) as usize);
    let mut tids: Vec<u64> = spans.iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    assert_eq!(tids.len(), THREADS, "each worker records under its own tid");
    for &tid in &tids {
        let ticks: Vec<_> = spans
            .iter()
            .filter(|s| s.tid == tid && s.phase == Phase::TickBuild)
            .collect();
        let children: Vec<_> = spans
            .iter()
            .filter(|s| s.tid == tid && s.phase == Phase::Attention)
            .collect();
        assert_eq!(ticks.len(), TICKS as usize);
        assert_eq!(children.len(), (TICKS * CHILDREN) as usize);
        // Every child interval sits inside a parent interval: begin/end
        // pairing survived the fan-out (complete spans are written at
        // guard drop, so a parent always outlives and encloses its
        // children on the shared monotonic timebase). ">= 1" rather
        // than "== 1": on a coarse clock two adjacent zero-duration
        // ticks can share a boundary timestamp with a degenerate child.
        for c in &children {
            let enclosing = ticks
                .iter()
                .filter(|t| {
                    t.start_ns <= c.start_ns && c.start_ns + c.dur_ns <= t.start_ns + t.dur_ns
                })
                .count();
            assert!(enclosing >= 1, "child span must nest inside a tick span");
        }
    }
    end();
}

// ---------------------------------------------------------- zero-alloc

/// System allocator wrapper counting this thread's allocation calls —
/// the counter is a const-init TLS cell so the accounting itself never
/// allocates, and parallel test threads don't perturb each other.
struct CountingAlloc;

thread_local! {
    static ALLOCS: Cell<u64> = const { Cell::new(0) };
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        unsafe { System.alloc(layout) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn disabled_mode_allocates_nothing() {
    let _g = serial();
    begin(TraceLevel::Off);
    let before = ALLOCS.with(|c| c.get());
    for i in 0..10_000u64 {
        let g = trace::span_id(Phase::SiteGate, i);
        std::hint::black_box(&g);
        drop(g);
        trace::record_duration(Phase::LmHead, i, Duration::from_nanos(5));
    }
    let after = ALLOCS.with(|c| c.get());
    assert_eq!(after, before, "disabled spans must not allocate");
    end();
}

// ------------------------------------------------------ bitwise identity

#[test]
fn tracing_never_changes_decode_bits() {
    let _g = serial();
    let cfg = EngineConfig {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq: 64,
    };
    let pattern = Pattern::NM { n: 8, m: 16 };
    let prompt: Vec<u32> = (0..12).map(|i| (i * 5 + 3) % 64).collect();
    let run = |level: TraceLevel| {
        begin(level);
        let mut engine =
            NativeEngine::synthetic(&cfg, 7, NativeSparsity::act(pattern)).expect("engine");
        let mut pool = engine.new_kv_pool();
        let mut kv = pool.new_cache();
        let tokens = engine.generate_greedy(&mut kv, &mut pool, &prompt, 24, &[]).unwrap();
        let bits: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
        end();
        (tokens, bits)
    };
    let (tok_off, bits_off) = run(TraceLevel::Off);
    let (tok_full, bits_full) = run(TraceLevel::Full);
    assert_eq!(tok_off, tok_full, "tracing changed generated tokens");
    assert_eq!(bits_off, bits_full, "tracing changed logit bits");
}

// ------------------------------------------------------- loadgen report

#[test]
fn loadgen_report_carries_phases_and_queue_wait() {
    let _g = serial();
    begin(TraceLevel::Off); // run() itself must raise to Metrics
    let cfg = LoadgenConfig {
        replicas: 2,
        queue_cap: 32,
        max_requests: 48,
        concurrency: 8,
        rate_rps: 0.0,
        mode: Mode::Mixed,
        max_new: 4,
        max_wait: Duration::from_millis(1),
        seed: 7,
        backend: BackendChoice::Synthetic {
            batch: 8,
            forward_cost: Duration::from_micros(20),
        },
        ..Default::default()
    };
    let report = loadgen::run(&cfg).expect("loadgen run");
    assert!(!report.phases.is_empty(), "loadgen must record a phases breakdown");
    let has = |p: Phase| report.phases.phases.iter().any(|a| a.phase == p && a.count > 0);
    assert!(has(Phase::QueueWait), "queue_wait spans missing");
    assert!(has(Phase::TickBuild), "tick_build spans missing");
    assert!(has(Phase::Reply), "reply spans missing");
    assert!(
        report.stats.queue_wait.count() as usize >= cfg.max_requests,
        "every dispatched or shed request must record a queue wait"
    );
    end();
}
