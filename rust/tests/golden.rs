//! Cross-language semantic pinning: replay the python oracle's golden
//! vectors (`artifacts/golden.json`, written by `aot.py`) through the
//! rust-native sparsity implementation. Skips when artifacts are absent
//! (pure-rust CI); `make test` always exercises it.

use nmsparse::sparsity::nm::nm_mask;
use nmsparse::sparsity::transforms::{mitigated_nm_prune, Shift};
use nmsparse::util::json;
use nmsparse::util::tensor::Tensor;
use std::path::Path;

fn load_golden() -> Option<json::Json> {
    let path = Path::new("artifacts/golden.json");
    if !path.exists() {
        eprintln!("golden.json missing — run `make artifacts`; skipping");
        return None;
    }
    Some(json::parse(&std::fs::read_to_string(path).unwrap()).unwrap())
}

fn floats(j: &json::Json) -> Vec<f32> {
    j.as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect()
}

#[test]
fn golden_nm_masks_match_python_oracle() {
    let Some(g) = load_golden() else { return };
    let mut checked = 0;
    for case in g.req("cases").unwrap().as_arr().unwrap() {
        if case.req("kind").unwrap().as_str() != Some("nm_mask") {
            continue;
        }
        let n = case.req("n").unwrap().as_usize().unwrap();
        let m = case.req("m").unwrap().as_usize().unwrap();
        let rows = case.req("rows").unwrap().as_usize().unwrap();
        let cols = case.req("cols").unwrap().as_usize().unwrap();
        let scores = floats(case.req("scores_abs").unwrap());
        let expected: Vec<bool> = case
            .req("mask")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() != 0.0)
            .collect();
        for r in 0..rows {
            let row = &scores[r * cols..(r + 1) * cols];
            let mask = nm_mask(row, n, m);
            assert_eq!(
                mask,
                expected[r * cols..(r + 1) * cols].to_vec(),
                "{n}:{m} row {r}"
            );
        }
        checked += 1;
    }
    assert!(checked >= 4, "expected nm_mask cases in golden file");
}

#[test]
fn golden_mitigated_prune_matches_python_oracle() {
    let Some(g) = load_golden() else { return };
    let mut checked = 0;
    for case in g.req("cases").unwrap().as_arr().unwrap() {
        if case.req("kind").unwrap().as_str() != Some("mitigated_prune_2_4") {
            continue;
        }
        let rows = case.req("rows").unwrap().as_usize().unwrap();
        let cols = case.req("cols").unwrap().as_usize().unwrap();
        let shift_mode = case.req("shift_mode").unwrap().as_f64().unwrap();
        let use_var = case.req("use_var").unwrap().as_f64().unwrap() == 1.0;
        let x = Tensor::from_vec(&[rows, cols], floats(case.req("x").unwrap()));
        let expected = Tensor::from_vec(&[rows, cols], floats(case.req("y").unwrap()));
        let shift = if shift_mode == 1.0 {
            Shift::DynamicPerToken
        } else {
            Shift::None
        };
        let y = mitigated_nm_prune(&x, 2, 4, shift, use_var);
        let d = y.max_abs_diff(&expected);
        assert!(
            d < 2e-4,
            "shift_mode={shift_mode} use_var={use_var}: max diff {d}"
        );
        checked += 1;
    }
    assert!(checked >= 4, "expected mitigated cases in golden file");
}
