//! Deterministic loopback tests for the multi-replica serving core: no
//! TCP, no artifacts — requests go straight into `ServerCore` over
//! channels against the synthetic backend (or a gated backend whose
//! completion the test controls), pinning:
//!
//! - admission control: the queue-depth cap rejects deterministically
//!   with `overloaded`, and rejections are counted, not queued;
//! - graceful drain: shutdown answers every admitted request before
//!   joining, and generate completions count toward `served` even when
//!   the client stopped listening;
//! - correctness: scores and generated tokens match the backend's
//!   deterministic formulas through the whole stage→batch→reply path.

use nmsparse::coordinator::server::{
    ReplicaBackend, Request, Response, ServerConfig, ServerCore, SubmitError, SyntheticBackend,
};
use nmsparse::launcher::loadgen::{make_request, Mode};
use std::sync::{mpsc, Mutex};
use std::time::Duration;

fn synth_core(replicas: usize, queue_cap: usize, batch: usize) -> ServerCore {
    ServerCore::start(
        ServerConfig { replicas, queue_cap, max_wait: Duration::from_millis(1) },
        move |_r| Ok(SyntheticBackend::new(batch, Duration::ZERO)),
    )
    .expect("core starts")
}

/// Replay of `SyntheticBackend::next_token` through the session rules
/// (stop token or budget) — what a Generate reply must contain.
fn expected_generation(prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut row = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let tok = SyntheticBackend::next_token(&row);
        out.push(tok);
        row.push(tok);
        if tok == SyntheticBackend::STOP {
            break;
        }
    }
    out
}

#[test]
fn mixed_workload_completes_with_correct_results() {
    let core = synth_core(2, 256, 4);
    let n = 60;
    let mut expected = Vec::new();
    let mut tickets = Vec::new();
    for idx in 0..n {
        let req = make_request(123, idx, Mode::Mixed, 6);
        let want = match &req {
            Request::Score { tokens, span } => {
                Response::Score { score: SyntheticBackend::score_of(tokens, *span) }
            }
            Request::Generate { tokens, max_new } => {
                Response::Generate { tokens: expected_generation(tokens, *max_new) }
            }
        };
        expected.push(want);
        tickets.push(core.submit(req).expect("queue cap is generous"));
    }
    for (ticket, want) in tickets.iter().zip(&expected) {
        let got = ticket.recv().expect("a terminal reply");
        assert_eq!(&got, want);
    }
    let stats = core.shutdown();
    assert_eq!(stats.served, n as u64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.latency.count(), n as u64);
    assert!(stats.latency.percentile(50.0) <= stats.latency.percentile(95.0));
    assert!(stats.latency.percentile(95.0) <= stats.latency.percentile(99.0));
    assert!(stats.batch_occupancy() > 0.0 && stats.batch_occupancy() <= 1.0);
    assert!(stats.batches > 0);
}

/// A backend whose forwards block until the test releases them — makes
/// admission-control timing deterministic (depth only drops when the
/// test says so).
struct GatedBackend {
    gate: mpsc::Receiver<()>,
}

impl ReplicaBackend for GatedBackend {
    fn batch(&self) -> usize {
        1
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> anyhow::Result<Vec<f64>> {
        self.gate.recv().ok(); // hold the request until released
        Ok(rows.iter().map(|_| 1.0).collect())
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> anyhow::Result<Vec<Option<u32>>> {
        self.gate.recv().ok();
        Ok(rows.iter().map(|_| Some(SyntheticBackend::STOP)).collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        vec![SyntheticBackend::STOP]
    }
}

#[test]
fn admission_cap_rejects_deterministically() {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let slot = Mutex::new(Some(gate_rx));
    let core = ServerCore::start(
        ServerConfig { replicas: 1, queue_cap: 2, max_wait: Duration::from_millis(1) },
        move |_r| Ok(GatedBackend { gate: slot.lock().unwrap().take().expect("one replica") }),
    )
    .unwrap();
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    // Depth only decreases on completion, and the gate blocks completion:
    // two requests fill the cap, the third is shed — no timing involved.
    let t1 = core.submit(req()).expect("first fits");
    let t2 = core.submit(req()).expect("second fits");
    let err = match core.submit(req()) {
        Ok(_) => panic!("third must be shed"),
        Err(e) => e,
    };
    assert_eq!(err, SubmitError::Overloaded { replica: 0 });
    assert_eq!(err.to_string(), "overloaded"); // the protocol error string
    // Release both held forwards; the admitted requests still complete.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    assert_eq!(t1.recv(), Some(Response::Score { score: 1.0 }));
    assert_eq!(t2.recv(), Some(Response::Score { score: 1.0 }));
    let stats = core.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.rejected, 1);
    assert!((stats.rejection_rate() - 1.0 / 3.0).abs() < 1e-12);
}

#[test]
fn shutdown_drains_every_admitted_request() {
    let core = synth_core(2, 64, 4);
    let mut tickets = Vec::new();
    for idx in 0..24 {
        tickets.push(core.submit(make_request(9, idx, Mode::Mixed, 5)).unwrap());
    }
    // Shut down immediately: drain must answer all 24 before joining.
    let stats = core.shutdown();
    assert_eq!(stats.served, 24);
    assert_eq!(stats.rejected, 0);
    for t in &tickets {
        assert!(t.try_recv().is_some(), "every ticket resolved before join");
    }
}

/// Gated backend that also announces when a forward *starts* — lets the
/// steal test know replica 0 is wedged inside its engine call before
/// piling work onto its queue.
struct NotifyGatedBackend {
    entered: mpsc::Sender<()>,
    gate: mpsc::Receiver<()>,
}

impl ReplicaBackend for NotifyGatedBackend {
    fn batch(&self) -> usize {
        1
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> anyhow::Result<Vec<f64>> {
        self.entered.send(()).ok();
        self.gate.recv().ok(); // blocks only while the test holds the tx
        Ok(rows.iter().map(|_| 1.0).collect())
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> anyhow::Result<Vec<Option<u32>>> {
        self.entered.send(()).ok();
        self.gate.recv().ok();
        Ok(rows.iter().map(|_| Some(SyntheticBackend::STOP)).collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        vec![SyntheticBackend::STOP]
    }
}

#[test]
fn idle_replica_steals_from_deepest_queue() {
    // All traffic is keyed to replica 0 (worst-case skewed session keys).
    // Replica 0 wedges inside its first forward; the idle replica 1 must
    // steal the staged backlog and answer it while 0 is still stuck.
    let (enter_tx, enter_rx) = mpsc::channel::<()>();
    let (gate0_tx, gate0_rx) = mpsc::channel::<()>();
    let (gate1_tx, gate1_rx) = mpsc::channel::<()>();
    drop(gate1_tx); // replica 1 never blocks (recv errors immediately)
    let slots = Mutex::new(vec![Some((enter_tx.clone(), gate0_rx)), Some((enter_tx, gate1_rx))]);
    let core = ServerCore::start(
        ServerConfig { replicas: 2, queue_cap: 16, max_wait: Duration::from_millis(1) },
        move |r| {
            let (entered, gate) = slots.lock().unwrap()[r].take().expect("one backend per replica");
            Ok(NotifyGatedBackend { entered, gate })
        },
    )
    .unwrap();
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    // First request reaches replica 0's engine and wedges there.
    let t0 = core.submit_with_key(Some(0), req()).unwrap();
    assert_eq!(t0.replica, 0);
    enter_rx.recv().expect("replica 0 entered its forward");
    // Backlog lands on replica 0's queue while it is stuck.
    let backlog: Vec<_> =
        (0..3).map(|_| core.submit_with_key(Some(0), req()).unwrap()).collect();
    for t in &backlog {
        assert_eq!(t.replica, 0, "affinity still routes to replica 0");
        // Replica 1 (idle, woken by the steal hint) must answer this
        // while replica 0 is still wedged.
        let resp = t.recv_timeout(Duration::from_secs(10)).expect("stolen request answered");
        assert_eq!(resp, Response::Score { score: 1.0 });
    }
    // Unwedge replica 0 so its held request finishes too.
    gate0_tx.send(()).unwrap();
    assert_eq!(t0.recv(), Some(Response::Score { score: 1.0 }));
    let handle = core.handle();
    let stats = core.shutdown(); // joins workers: all counters final
    let per_replica = handle.replica_stats();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.stolen, 3, "all three backlog requests were stolen");
    assert_eq!(per_replica[1].stolen, 3, "replica 1 did the stealing");
    assert_eq!(per_replica[1].served, 3);
    assert_eq!(per_replica[0].served, 1);
}

#[test]
fn generate_completion_counts_without_listener() {
    // A client that disconnects mid-generation must not stall
    // --max-requests accounting: completions count at reap time whether
    // or not the reply channel still has a receiver.
    let core = synth_core(1, 16, 2);
    let t = core
        .submit(Request::Generate { tokens: vec![7, 8, 9], max_new: 4 })
        .unwrap();
    drop(t); // client gone before the session finishes
    let t2 = core.submit(Request::Score { tokens: vec![3, 4], span: (1, 2) }).unwrap();
    assert!(t2.recv().is_some());
    let stats = core.shutdown();
    assert_eq!(stats.served, 2, "dropped-listener generate still served");
    assert_eq!(stats.errors, 0);
}
