//! Deterministic loopback tests for the multi-replica serving core: no
//! TCP, no artifacts — requests go straight into `ServerCore` over
//! channels against the synthetic backend (or a gated backend whose
//! completion the test controls), pinning:
//!
//! - admission control: the queue-depth cap rejects deterministically
//!   with `overloaded`, and rejections are counted, not queued;
//! - graceful drain: shutdown answers every admitted request before
//!   joining, and generate completions count toward `served` even when
//!   the client stopped listening;
//! - correctness: scores and generated tokens match the backend's
//!   deterministic formulas through the whole stage→batch→reply path;
//! - supervision: injected backend panics/errors ([`ChaosBackend`])
//!   resolve every in-flight request terminally (`replica_failed`, or a
//!   transparent sibling retry for idempotent scores), the replica
//!   rebuilds and serves again, and expired deadlines shed with
//!   `timeout` — exactly-once accounting throughout.

use nmsparse::coordinator::chaos::{ChaosBackend, ChaosHandle, FaultPlan};
use nmsparse::coordinator::server::{
    NativeBackend, ReplicaBackend, Request, Response, ServerConfig, ServerCore, StepOutcome,
    SubmitError, SyntheticBackend, ERR_REPLICA_FAILED, ERR_TIMEOUT,
};
use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::launcher::loadgen::{make_request, Mode};
use nmsparse::sparsity::Pattern;
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

fn synth_core(replicas: usize, queue_cap: usize, batch: usize) -> ServerCore {
    ServerCore::start(
        ServerConfig {
            replicas,
            queue_cap,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        move |_r| Ok(SyntheticBackend::new(batch, Duration::ZERO)),
    )
    .expect("core starts")
}

/// Replay of `SyntheticBackend::next_token` through the session rules
/// (stop token or budget) — what a Generate reply must contain.
fn expected_generation(prompt: &[u32], max_new: usize) -> Vec<u32> {
    let mut row = prompt.to_vec();
    let mut out = Vec::new();
    for _ in 0..max_new {
        let tok = SyntheticBackend::next_token(&row);
        out.push(tok);
        row.push(tok);
        if tok == SyntheticBackend::STOP {
            break;
        }
    }
    out
}

#[test]
fn mixed_workload_completes_with_correct_results() {
    let core = synth_core(2, 256, 4);
    let n = 60;
    let mut expected = Vec::new();
    let mut tickets = Vec::new();
    for idx in 0..n {
        let req = make_request(123, idx, Mode::Mixed, 6);
        let want = match &req {
            Request::Score { tokens, span } => {
                Response::Score { score: SyntheticBackend::score_of(tokens, *span) }
            }
            Request::Generate { tokens, max_new } => {
                Response::Generate { tokens: expected_generation(tokens, *max_new) }
            }
        };
        expected.push(want);
        tickets.push(core.submit(req).expect("queue cap is generous"));
    }
    for (ticket, want) in tickets.iter().zip(&expected) {
        let got = ticket.recv().expect("a terminal reply");
        assert_eq!(&got, want);
    }
    let stats = core.shutdown();
    assert_eq!(stats.served, n as u64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.latency.count(), n as u64);
    assert!(stats.latency.percentile(50.0) <= stats.latency.percentile(95.0));
    assert!(stats.latency.percentile(95.0) <= stats.latency.percentile(99.0));
    assert!(stats.batch_occupancy() > 0.0 && stats.batch_occupancy() <= 1.0);
    assert!(stats.batches > 0);
}

/// A backend whose forwards block until the test releases them — makes
/// admission-control timing deterministic (depth only drops when the
/// test says so).
struct GatedBackend {
    gate: mpsc::Receiver<()>,
}

impl ReplicaBackend for GatedBackend {
    fn batch(&self) -> usize {
        1
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> anyhow::Result<Vec<f64>> {
        self.gate.recv().ok(); // hold the request until released
        Ok(rows.iter().map(|_| 1.0).collect())
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> anyhow::Result<Vec<StepOutcome>> {
        self.gate.recv().ok();
        Ok(rows.iter().map(|_| StepOutcome::Token(SyntheticBackend::STOP)).collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        vec![SyntheticBackend::STOP]
    }
}

#[test]
fn admission_cap_rejects_deterministically() {
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let slot = Mutex::new(Some(gate_rx));
    let core = ServerCore::start(
        ServerConfig {
            replicas: 1,
            queue_cap: 2,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        move |_r| Ok(GatedBackend { gate: slot.lock().unwrap().take().expect("one replica") }),
    )
    .unwrap();
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    // Depth only decreases on completion, and the gate blocks completion:
    // two requests fill the cap, the third is shed — no timing involved.
    let t1 = core.submit(req()).expect("first fits");
    let t2 = core.submit(req()).expect("second fits");
    let err = match core.submit(req()) {
        Ok(_) => panic!("third must be shed"),
        Err(e) => e,
    };
    assert_eq!(err, SubmitError::Overloaded { replica: 0 });
    assert_eq!(err.to_string(), "overloaded"); // the protocol error string
    // Release both held forwards; the admitted requests still complete.
    gate_tx.send(()).unwrap();
    gate_tx.send(()).unwrap();
    assert_eq!(t1.recv(), Some(Response::Score { score: 1.0 }));
    assert_eq!(t2.recv(), Some(Response::Score { score: 1.0 }));
    let stats = core.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.rejected, 1);
    assert!((stats.rejection_rate() - 1.0 / 3.0).abs() < 1e-12);
}

#[test]
fn shutdown_drains_every_admitted_request() {
    let core = synth_core(2, 64, 4);
    let mut tickets = Vec::new();
    for idx in 0..24 {
        tickets.push(core.submit(make_request(9, idx, Mode::Mixed, 5)).unwrap());
    }
    // Shut down immediately: drain must answer all 24 before joining.
    let stats = core.shutdown();
    assert_eq!(stats.served, 24);
    assert_eq!(stats.rejected, 0);
    for t in &tickets {
        assert!(t.try_recv().is_some(), "every ticket resolved before join");
    }
}

/// Gated backend that also announces when a forward *starts* — lets the
/// steal test know replica 0 is wedged inside its engine call before
/// piling work onto its queue.
struct NotifyGatedBackend {
    entered: mpsc::Sender<()>,
    gate: mpsc::Receiver<()>,
}

impl ReplicaBackend for NotifyGatedBackend {
    fn batch(&self) -> usize {
        1
    }

    fn score_rows(&mut self, rows: &[(Vec<u32>, (usize, usize))]) -> anyhow::Result<Vec<f64>> {
        self.entered.send(()).ok();
        self.gate.recv().ok(); // blocks only while the test holds the tx
        Ok(rows.iter().map(|_| 1.0).collect())
    }

    fn decode_step_sessions(&mut self, rows: &[(u64, &[u32])]) -> anyhow::Result<Vec<StepOutcome>> {
        self.entered.send(()).ok();
        self.gate.recv().ok();
        Ok(rows.iter().map(|_| StepOutcome::Token(SyntheticBackend::STOP)).collect())
    }

    fn stop_tokens(&self) -> Vec<u32> {
        vec![SyntheticBackend::STOP]
    }
}

#[test]
fn idle_replica_steals_from_deepest_queue() {
    // All traffic is keyed to replica 0 (worst-case skewed session keys).
    // Replica 0 wedges inside its first forward; the idle replica 1 must
    // steal the staged backlog and answer it while 0 is still stuck.
    let (enter_tx, enter_rx) = mpsc::channel::<()>();
    let (gate0_tx, gate0_rx) = mpsc::channel::<()>();
    let (gate1_tx, gate1_rx) = mpsc::channel::<()>();
    drop(gate1_tx); // replica 1 never blocks (recv errors immediately)
    let slots = Mutex::new(vec![Some((enter_tx.clone(), gate0_rx)), Some((enter_tx, gate1_rx))]);
    let core = ServerCore::start(
        ServerConfig {
            replicas: 2,
            queue_cap: 16,
            max_wait: Duration::from_millis(1),
            ..Default::default()
        },
        move |r| {
            let (entered, gate) = slots.lock().unwrap()[r].take().expect("one backend per replica");
            Ok(NotifyGatedBackend { entered, gate })
        },
    )
    .unwrap();
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    // First request reaches replica 0's engine and wedges there.
    let t0 = core.submit_with_key(Some(0), req()).unwrap();
    assert_eq!(t0.replica, 0);
    enter_rx.recv().expect("replica 0 entered its forward");
    // Backlog lands on replica 0's queue while it is stuck.
    let backlog: Vec<_> =
        (0..3).map(|_| core.submit_with_key(Some(0), req()).unwrap()).collect();
    for t in &backlog {
        assert_eq!(t.replica, 0, "affinity still routes to replica 0");
        // Replica 1 (idle, woken by the steal hint) must answer this
        // while replica 0 is still wedged.
        let resp = t.recv_timeout(Duration::from_secs(10)).expect("stolen request answered");
        assert_eq!(resp, Response::Score { score: 1.0 });
    }
    // Unwedge replica 0 so its held request finishes too.
    gate0_tx.send(()).unwrap();
    assert_eq!(t0.recv(), Some(Response::Score { score: 1.0 }));
    let handle = core.handle();
    let stats = core.shutdown(); // joins workers: all counters final
    let per_replica = handle.replica_stats();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.errors, 0);
    assert_eq!(stats.stolen, 3, "all three backlog requests were stolen");
    assert_eq!(per_replica[1].stolen, 3, "replica 1 did the stealing");
    assert_eq!(per_replica[1].served, 3);
    assert_eq!(per_replica[0].served, 1);
}

/// A supervised synthetic core whose replicas share externally-created
/// chaos handles — the handle survives rebuilds, so one-shot faults fire
/// exactly once even though the factory runs again after each crash.
fn chaos_core(
    handles: Vec<Option<ChaosHandle>>,
    queue_cap: usize,
    backoff: Duration,
    backoff_cap: Duration,
) -> ServerCore {
    let replicas = handles.len();
    ServerCore::start(
        ServerConfig {
            replicas,
            queue_cap,
            max_wait: Duration::from_millis(1),
            restart_backoff: backoff,
            restart_backoff_cap: backoff_cap,
        },
        move |r| {
            Ok(ChaosBackend::new(SyntheticBackend::new(4, Duration::ZERO), handles[r].clone()))
        },
    )
    .expect("core starts")
}

#[test]
fn expired_deadline_sheds_with_timeout_reply() {
    let core = chaos_core(vec![None], 16, Duration::from_millis(1), Duration::from_millis(5));
    let req = Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    // Deadline already expired at submit: admission accepts it (the cap
    // is the only admission rule), but the flush path must shed it with
    // a terminal `timeout` reply instead of spending a batch lane.
    let t = core.submit_with(None, req, Some(Instant::now())).unwrap();
    let resp = t.recv().expect("terminal reply");
    assert_eq!(resp, Response::Error { message: ERR_TIMEOUT.into() });
    let live = Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    let t2 = core.submit_with(None, live, Some(Instant::now() + Duration::from_secs(30))).unwrap();
    assert!(matches!(t2.recv(), Some(Response::Score { .. })), "live deadline still serves");
    let stats = core.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.timed_out, 1);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.latency.count(), 2, "timed-out requests are still accounted terminally");
}

#[test]
fn failed_score_retries_transparently_on_a_sibling() {
    // Replica 0 panics on its very first engine op; replica 1 is healthy.
    let h0 = ChaosHandle::new(FaultPlan::parse("panic@1").unwrap());
    let core = chaos_core(
        vec![Some(h0), None],
        64,
        Duration::from_millis(500),
        Duration::from_millis(500),
    );
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    let want = Response::Score { score: SyntheticBackend::score_of(&[4, 5, 6], (1, 3)) };
    // Keyed to replica 0: its first op panics, and the supervisor must
    // requeue the in-flight score on replica 1 — the client sees the
    // correct answer, never `replica_failed`.
    let t = core.submit_with_key(Some(0), req()).unwrap();
    assert_eq!(t.recv_timeout(Duration::from_secs(10)), Some(want.clone()));
    // Backlog keyed to the now-dead replica 0 stays put: the idle
    // replica 1 must NOT steal from a dead sibling (its staged work is
    // served by the rebuilt engine, preserving session affinity).
    let backlog: Vec<_> = (0..3).map(|_| core.submit_with_key(Some(0), req()).unwrap()).collect();
    std::thread::sleep(Duration::from_millis(30));
    for t in &backlog {
        assert!(t.try_recv().is_none(), "no stealing from a dead replica");
    }
    // After the 500 ms backoff the factory rebuilds replica 0 (the
    // panic fault is consumed — the shared handle survives the rebuild)
    // and the staged backlog serves normally.
    for t in &backlog {
        assert_eq!(t.recv_timeout(Duration::from_secs(10)), Some(want.clone()));
    }
    let handle = core.handle();
    let stats = core.shutdown();
    let per = handle.replica_stats();
    assert_eq!(stats.served, 4);
    assert_eq!(stats.errors, 0, "the retried score is not an error");
    assert_eq!(stats.retried, 1);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.stolen, 0);
    assert_eq!(per[0].restarts, 1);
    assert_eq!(per[0].served, 3, "rebuilt replica served its staged backlog");
}

#[test]
fn generate_fails_fast_with_replica_failed() {
    // Generates are stateful (the session's KV died with the engine), so
    // they fail fast with a distinguishable error instead of retrying.
    let h = ChaosHandle::new(FaultPlan::parse("panic@1").unwrap());
    let core =
        chaos_core(vec![Some(h)], 16, Duration::from_millis(1), Duration::from_millis(5));
    let t = core.submit(Request::Generate { tokens: vec![7, 8, 9], max_new: 4 }).unwrap();
    assert_eq!(
        t.recv_timeout(Duration::from_secs(10)),
        Some(Response::Error { message: ERR_REPLICA_FAILED.into() })
    );
    // The same replica serves again after its rebuild.
    let t2 = core.submit(Request::Score { tokens: vec![3, 4], span: (1, 2) }).unwrap();
    let want = Response::Score { score: SyntheticBackend::score_of(&[3, 4], (1, 2)) };
    assert_eq!(t2.recv_timeout(Duration::from_secs(10)), Some(want));
    let stats = core.shutdown();
    assert_eq!(stats.served, 2);
    assert_eq!(stats.failed, 1);
    assert_eq!(stats.errors, 1);
    assert_eq!(stats.restarts, 1);
    assert_eq!(stats.completed(), stats.submitted);
}

#[test]
fn chaos_soak_exactly_once_terminal_outcomes() {
    // Seeded fault plans on both replicas (≥1 early panic each, plus
    // errors and stalls), a mixed keyed workload, and a sprinkle of
    // already-expired deadlines: every submitted request must reach
    // exactly one terminal outcome and the books must balance.
    let handles: Vec<Option<ChaosHandle>> =
        (0..2).map(|r| Some(ChaosHandle::seeded(0xBEEF ^ r as u64, 40))).collect();
    let core =
        chaos_core(handles, 512, Duration::from_millis(1), Duration::from_millis(20));
    let n = 140usize;
    let mut tickets = Vec::with_capacity(n);
    for idx in 0..n {
        let req = make_request(777, idx, Mode::Mixed, 5);
        let deadline = if idx % 10 == 0 {
            Some(Instant::now()) // expired on arrival -> must shed as timeout
        } else {
            Some(Instant::now() + Duration::from_secs(30))
        };
        tickets.push(core.submit_with(Some(idx as u64 % 2), req, deadline).unwrap());
    }
    let mut error_replies = 0u64;
    for t in &tickets {
        let resp = t.recv_timeout(Duration::from_secs(60)).expect("exactly one terminal reply");
        if let Response::Error { message } = &resp {
            error_replies += 1;
            assert!(
                message == ERR_TIMEOUT || message == ERR_REPLICA_FAILED,
                "unexpected terminal error '{message}'"
            );
        }
        assert!(t.try_recv().is_none(), "no second reply for any ticket");
    }
    let handle = core.handle();
    let stats = core.shutdown();
    let per = handle.replica_stats();
    assert_eq!(stats.submitted, n as u64);
    assert_eq!(stats.rejected, 0);
    assert_eq!(stats.served, n as u64, "every request reached a terminal outcome");
    assert_eq!(stats.latency.count(), stats.served);
    assert_eq!(stats.errors, error_replies);
    assert_eq!(stats.errors, stats.timed_out + stats.failed);
    assert_eq!(stats.timed_out, 14, "every expired deadline shed (n/10 of {n})");
    for (r, p) in per.iter().enumerate() {
        assert!(p.restarts >= 1, "replica {r} panicked and was rebuilt (restarts = 0)");
    }
    assert!(
        stats.retried + stats.failed >= 2,
        "each panic had in-flight work (retried {} failed {})",
        stats.retried,
        stats.failed
    );
}

#[test]
fn shutdown_while_dead_fails_staged_work_terminally() {
    // A replica that dies with a huge backoff, then shutdown: drain must
    // terminate anyway, answering staged work with `replica_failed`
    // rather than waiting out the rebuild.
    let h = ChaosHandle::new(FaultPlan::parse("panic@1").unwrap());
    let core = chaos_core(vec![Some(h)], 16, Duration::from_secs(5), Duration::from_secs(5));
    let req = || Request::Score { tokens: vec![4, 5, 6], span: (1, 3) };
    let t1 = core.submit(req()).unwrap();
    let failed = Response::Error { message: ERR_REPLICA_FAILED.into() };
    // No sibling exists, so the in-flight score fails terminally.
    assert_eq!(t1.recv_timeout(Duration::from_secs(10)), Some(failed.clone()));
    // Stage more work while the replica is dead (5 s from rebuilding).
    let t2 = core.submit(req()).unwrap();
    let t3 = core.submit(Request::Generate { tokens: vec![7, 8], max_new: 3 }).unwrap();
    let stats = core.shutdown(); // must not wait 5 s
    assert_eq!(t2.try_recv(), Some(failed.clone()));
    assert_eq!(t3.try_recv(), Some(failed));
    assert_eq!(stats.served, 3);
    assert_eq!(stats.failed, 3);
    assert_eq!(stats.restarts, 0, "the backoff never elapsed");
    assert_eq!(stats.completed(), stats.submitted);
}

#[test]
fn restarted_native_replica_reprefills_generate_sessions_at_cap_1() {
    // Restart-under-eviction regression: a KV-cached native replica at
    // session cap 1 (every step evicts and re-prefills) panics mid-decode,
    // rebuilds, and must then produce bitwise-identical generations.
    let cfg = EngineConfig {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq: 32,
    };
    let pattern = Pattern::NM { n: 8, m: 16 };
    let stop: Vec<u32> = vec![2];
    let max_new = 12;
    let prompts: [Vec<u32>; 3] = [vec![3, 7, 11], vec![40, 1, 2, 3, 4], vec![9]];
    // Reference: the sequential sliding-window loop on an identical model.
    let mut engine = NativeEngine::synthetic(&cfg, 5, NativeSparsity::act(pattern)).unwrap();
    let mut pool = engine.new_kv_pool();
    let mut kv = pool.new_cache();
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| engine.generate_greedy_sliding(&mut kv, &mut pool, p, max_new, &stop).unwrap())
        .collect();
    let h = ChaosHandle::new(FaultPlan::parse("panic@1").unwrap());
    let handle_for_factory = h.clone();
    let stop_f = stop.clone();
    let core = ServerCore::start(
        ServerConfig {
            replicas: 1,
            queue_cap: 32,
            max_wait: Duration::from_millis(1),
            restart_backoff: Duration::from_millis(1),
            restart_backoff_cap: Duration::from_millis(5),
        },
        move |_r| {
            let backend =
                NativeBackend::synthetic(&cfg, 5, NativeSparsity::act(pattern), stop_f.clone(), 4)?
                    .with_session_cap(1);
            Ok(ChaosBackend::new(backend, Some(handle_for_factory.clone())))
        },
    )
    .unwrap();
    // Wave 1: the first decode tick panics, so the sessions in that tick
    // fail fast; anything still staged serves after the rebuild.
    let wave1: Vec<_> = prompts
        .iter()
        .map(|p| core.submit(Request::Generate { tokens: p.clone(), max_new }).unwrap())
        .collect();
    let mut failed_replies = 0u64;
    for (t, w) in wave1.iter().zip(&want) {
        match t.recv_timeout(Duration::from_secs(30)).expect("terminal reply") {
            Response::Generate { tokens } => assert_eq!(&tokens, w, "post-rebuild bitwise match"),
            Response::Error { message } => {
                assert_eq!(message, ERR_REPLICA_FAILED);
                failed_replies += 1;
            }
            other => panic!("unexpected reply {other:?}"),
        }
    }
    assert!(failed_replies >= 1, "the panicking tick had at least one session in flight");
    // Wave 2 on the rebuilt replica: the fault is consumed, so all three
    // concurrent cap-1 sessions must re-prefill to the reference bits.
    let wave2: Vec<_> = prompts
        .iter()
        .map(|p| core.submit(Request::Generate { tokens: p.clone(), max_new }).unwrap())
        .collect();
    for (t, w) in wave2.iter().zip(&want) {
        assert_eq!(
            t.recv_timeout(Duration::from_secs(30)),
            Some(Response::Generate { tokens: w.clone() })
        );
    }
    assert_eq!(h.remaining(), 0, "the injected panic fired exactly once");
    let stats = core.shutdown();
    assert_eq!(stats.restarts, 1);
    assert_eq!(stats.failed, failed_replies);
    assert_eq!(stats.completed(), stats.submitted);
}

#[test]
fn generate_completion_counts_without_listener() {
    // A client that disconnects mid-generation must not stall
    // --max-requests accounting: completions count at reap time whether
    // or not the reply channel still has a receiver.
    let core = synth_core(1, 16, 2);
    let t = core
        .submit(Request::Generate { tokens: vec![7, 8, 9], max_new: 4 })
        .unwrap();
    drop(t); // client gone before the session finishes
    let t2 = core.submit(Request::Score { tokens: vec![3, 4], span: (1, 2) }).unwrap();
    assert!(t2.recv().is_some());
    let stats = core.shutdown();
    assert_eq!(stats.served, 2, "dropped-listener generate still served");
    assert_eq!(stats.errors, 0);
}
