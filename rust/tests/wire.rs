//! Wire-subsystem integration suite (DESIGN.md §2.15): codec roundtrip
//! properties (binary ≡ JSON on every message shape), malformed-frame
//! rejection with per-frame resynchronization, the versioned connect
//! handshake, the streamed-vs-buffered transcript-identity pin, and
//! weighted-fair admission under a 10:1 tenant skew.

use nmsparse::coordinator::server::{
    Request, Response, ServerConfig, ServerCore, SubmitOpts, SyntheticBackend,
};
use nmsparse::util::json::Json;
use nmsparse::util::prng::Rng;
use nmsparse::wire::binary;
use nmsparse::wire::{
    stream_channel, Codec, CodecKind, StreamOutcome, StreamPoll, WireReply, WireRequest, LANE_CAP,
};
use std::time::Duration;

/// Text corpus that exercises the escaping paths: quotes, backslashes,
/// newlines (which must never split a JSON frame), and control bytes.
fn arb_text(rng: &mut Rng) -> String {
    let atoms = ["plain", "with \"quotes\"", "back\\slash", "new\nline", "tab\there", "ctrl\u{1}"];
    let mut s = String::new();
    for _ in 0..rng.range(1, 4) {
        s.push_str(atoms[rng.below(atoms.len())]);
        s.push(' ');
    }
    s
}

fn arb_toks(rng: &mut Rng) -> Vec<u32> {
    let len = rng.below(10);
    (0..len).map(|_| rng.below(200) as u32).collect()
}

fn arb_request(rng: &mut Rng, i: usize) -> WireRequest {
    match i % 6 {
        0 => WireRequest::Ping,
        1 => WireRequest::Stats,
        2 => WireRequest::Score {
            text: arb_text(rng),
            choice: arb_text(rng),
            tenant: (i % 4 == 2).then(|| rng.below(9).to_string()),
        },
        3 => WireRequest::Generate {
            text: arb_text(rng),
            max_new: (i % 2 == 1).then(|| rng.range(1, 48)),
            tenant: (i % 4 == 3).then(|| "acme".to_string()),
            stream: i % 5 == 0,
        },
        4 => WireRequest::ScoreTokens {
            tokens: arb_toks(rng),
            span: (rng.below(8) as u32, rng.below(8) as u32),
            tenant: rng.below(7) as u32,
        },
        _ => WireRequest::GenerateTokens {
            tokens: arb_toks(rng),
            max_new: rng.range(1, 48) as u32,
            tenant: rng.below(7) as u32,
            stream: i % 2 == 0,
        },
    }
}

fn arb_reply(rng: &mut Rng, i: usize) -> WireReply {
    let outcomes = [StreamOutcome::End, StreamOutcome::Timeout, StreamOutcome::ReplicaFailed];
    match i % 6 {
        0 => {
            // A shape that is not a score/generate/error/chunk/end reply,
            // so the JSON codec keeps it a Blob on decode.
            let mut j = Json::obj();
            j.insert("pong", true.into());
            j.insert("uptime_s", rng.f64().into());
            WireReply::Blob(j)
        }
        1 => WireReply::Score { score: -10.0 * rng.f64() - 0.015625 },
        2 => WireReply::Generate { tokens: arb_toks(rng), text: arb_text(rng) },
        3 => WireReply::Chunk { index: rng.below(64) as u32, token: rng.below(200) as u32 },
        4 => WireReply::End {
            outcome: outcomes[i % 3],
            tokens: arb_toks(rng),
            text: arb_text(rng),
        },
        _ => WireReply::Error { message: arb_text(rng) },
    }
}

/// Both codecs roundtrip every message shape losslessly and consume
/// exactly the bytes they produced — the binary codec must agree with
/// the JSON oracle on what each message means.
#[test]
fn codecs_roundtrip_all_message_shapes() {
    let mut rng = Rng::new(0x11ce);
    for kind in [CodecKind::Json, CodecKind::Binary] {
        let c = kind.codec();
        for i in 0..240 {
            let req = arb_request(&mut rng, i);
            let mut buf = Vec::new();
            c.encode_request(&req, &mut buf);
            let (back, used) = c.decode_request(&buf).unwrap().expect("whole frame");
            assert_eq!(back, req, "{} request roundtrip", c.name());
            assert_eq!(used, buf.len(), "{} consumed exactly one frame", c.name());

            let rep = arb_reply(&mut rng, i);
            let mut buf = Vec::new();
            c.encode_reply(&rep, &mut buf);
            let (back, used) = c.decode_reply(&buf).unwrap().expect("whole frame");
            assert_eq!(back, rep, "{} reply roundtrip", c.name());
            assert_eq!(used, buf.len());
        }
    }
}

/// Back-to-back frames decode independently; a split frame reports
/// "need more bytes" rather than an error.
#[test]
fn codecs_delimit_pipelined_and_partial_frames() {
    let mut rng = Rng::new(0xfeed);
    for kind in [CodecKind::Json, CodecKind::Binary] {
        let c = kind.codec();
        let reqs: Vec<WireRequest> = (0..8).map(|i| arb_request(&mut rng, i)).collect();
        let mut buf = Vec::new();
        for r in &reqs {
            c.encode_request(r, &mut buf);
        }
        let mut pos = 0;
        for r in &reqs {
            let (back, used) = c.decode_request(&buf[pos..]).unwrap().expect("frame");
            assert_eq!(&back, r);
            pos += used;
        }
        assert_eq!(pos, buf.len());
        // Every strict prefix of a single frame is "need more bytes".
        let mut one = Vec::new();
        c.encode_request(&reqs[0], &mut one);
        for cut in 0..one.len() {
            assert!(
                matches!(c.decode_request(&one[..cut]), Ok(None)),
                "{} prefix of {cut}/{} bytes must be incomplete",
                c.name(),
                one.len()
            );
        }
    }
}

/// A malformed frame is rejected frame-local: the error reports how many
/// bytes to skip and the next frame decodes cleanly — one bad client
/// message must not kill the connection.
#[test]
fn malformed_frames_reject_without_losing_resync() {
    let c = CodecKind::Binary.codec();
    let mut buf = Vec::new();
    // Unknown tag, length-prefix intact.
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.push(0x7f);
    c.encode_request(&WireRequest::Ping, &mut buf);
    let err = c.decode_request(&buf).unwrap_err();
    assert_eq!(err.consumed, 5, "skip exactly the delimited bad frame");
    assert!(err.message.contains("unknown request tag"), "{}", err.message);
    let (back, _) = c.decode_request(&buf[err.consumed..]).unwrap().expect("resynced");
    assert_eq!(back, WireRequest::Ping);

    // Truncated body inside an intact envelope: tag says score_tokens but
    // the body ends early.
    let mut bad = Vec::new();
    bad.extend_from_slice(&3u32.to_le_bytes());
    bad.extend_from_slice(&[0x05, 0x01, 0x02]);
    let err = c.decode_request(&bad).unwrap_err();
    assert_eq!(err.consumed, 7);
    assert!(err.message.contains("truncated"), "{}", err.message);

    // Token count beyond the frame is rejected before allocation.
    let mut flood = Vec::new();
    flood.extend_from_slice(&17u32.to_le_bytes());
    flood.push(0x05); // score_tokens
    flood.extend_from_slice(&0u32.to_le_bytes()); // tenant
    flood.extend_from_slice(&0u32.to_le_bytes()); // span.0
    flood.extend_from_slice(&1u32.to_le_bytes()); // span.1
    flood.extend_from_slice(&u32::MAX.to_le_bytes()); // token count
    let err = c.decode_request(&flood).unwrap_err();
    assert!(err.message.contains("token count"), "{}", err.message);

    // A zero length prefix cannot delimit a frame.
    let err = c.decode_request(&0u32.to_le_bytes()).unwrap_err();
    assert_eq!(err.consumed, 4);

    // JSON oracle behaves the same way: a garbage line is skipped whole
    // and the following line still parses.
    let j = CodecKind::Json.codec();
    let mut buf = b"{not json\n".to_vec();
    j.encode_request(&WireRequest::Stats, &mut buf);
    let err = j.decode_request(&buf).unwrap_err();
    assert_eq!(err.consumed, 10);
    let (back, _) = j.decode_request(&buf[err.consumed..]).unwrap().expect("resynced");
    assert_eq!(back, WireRequest::Stats);
}

#[test]
fn handshake_rejects_magic_and_version_mismatches() {
    let good = binary::hello();
    assert_eq!(binary::check_hello(&good), Ok(()));
    assert_eq!(good.len(), binary::HELLO_LEN);

    let mut bad_magic = good;
    bad_magic[0] = b'{'; // a JSON client talking to a binary port
    let err = binary::check_hello(&bad_magic).unwrap_err();
    assert!(err.contains("bad magic"), "{err}");

    let mut bad_version = good;
    bad_version[4..].copy_from_slice(&(binary::VERSION + 1).to_le_bytes());
    let err = binary::check_hello(&bad_version).unwrap_err();
    assert!(err.contains("version mismatch"), "{err}");

    let err = binary::check_hello(&good[..3]).unwrap_err();
    assert!(err.contains("short hello"), "{err}");
}

/// Streaming changes delivery, never content: for the same request the
/// chunk-frame token sequence equals the terminal reply's token list,
/// which equals the buffered run's — and chunks actually flow.
#[test]
fn streamed_generate_matches_buffered_transcript() {
    let core = ServerCore::start(
        ServerConfig { replicas: 1, queue_cap: 64, ..Default::default() },
        |_r| Ok(SyntheticBackend::new(4, Duration::ZERO)),
    )
    .unwrap();
    let handle = core.handle();
    let mut total_chunks = 0usize;
    for i in 0..12u32 {
        let req = Request::Generate { tokens: vec![3 + i, 7, 9 + i % 5], max_new: 6 };
        let ticket = handle.submit_opts(req.clone(), SubmitOpts::default()).unwrap();
        let Some(Response::Generate { tokens: buffered }) = ticket.recv() else {
            panic!("buffered generate failed");
        };

        let (tx, rx) = stream_channel(LANE_CAP);
        let opts = SubmitOpts { stream: Some(tx), ..Default::default() };
        let ticket = handle.submit_opts(req, opts).unwrap();
        let mut chunks = Vec::new();
        loop {
            match rx.poll(Duration::from_millis(10)) {
                StreamPoll::Token(t) => chunks.push(t),
                StreamPoll::Idle => {}
                StreamPoll::Closed => break,
            }
        }
        let Some(Response::Generate { tokens: streamed }) = ticket.recv() else {
            panic!("streamed generate failed");
        };
        assert_eq!(streamed, buffered, "streaming changed the decoded tokens");
        assert_eq!(chunks, streamed, "chunk frames are the terminal token list");
        total_chunks += chunks.len();
    }
    core.shutdown();
    assert!(total_chunks > 0, "no incremental frames were delivered");
}

/// Deficit-round-robin admission under a 10:1 skew: a light tenant
/// submitted *behind* a heavy tenant's backlog still dispatches early,
/// so its queue-wait p95 sits well below the heavy tenant's (plain FIFO
/// would put it at the very tail).
#[test]
fn weighted_fair_dispatch_shields_light_tenant() {
    let core = ServerCore::start(
        ServerConfig {
            replicas: 1,
            queue_cap: 128,
            max_wait: Duration::from_millis(1),
            tenants: 2,
            ..Default::default()
        },
        |_r| Ok(SyntheticBackend::new(4, Duration::from_millis(1))),
    )
    .unwrap();
    let handle = core.handle();
    let score = |i: u32| Request::Score { tokens: vec![3 + i % 50, 9, 11, 13], span: (1, 3) };
    let mut tickets = Vec::new();
    for i in 0..60 {
        let opts = SubmitOpts { tenant: 0, ..Default::default() };
        tickets.push(handle.submit_opts(score(i), opts).unwrap());
    }
    for i in 0..6 {
        let opts = SubmitOpts { tenant: 1, ..Default::default() };
        tickets.push(handle.submit_opts(score(100 + i), opts).unwrap());
    }
    for t in &tickets {
        assert!(matches!(t.recv(), Some(Response::Score { .. })));
    }
    let stats = core.shutdown();
    assert_eq!(stats.tenants.len(), 2);
    assert_eq!(stats.tenants[0].served, 60);
    assert_eq!(stats.tenants[1].served, 6);
    let heavy_p95 = stats.tenants[0].queue_wait.percentile(95.0);
    let light_p95 = stats.tenants[1].queue_wait.percentile(95.0);
    assert!(
        light_p95 < heavy_p95 * 0.8,
        "light tenant p95 {light_p95:.4}s not shielded from heavy p95 {heavy_p95:.4}s"
    );
}
