//! Property suite for the batched session-stepping API and the paged KV
//! substrate it runs on:
//!
//! - `step_batch` over K concurrent sessions is **bitwise
//!   logits-identical** to K sequential per-session `step` loops, across
//!   patterns (2:4 / 8:16 / 16:32 / dense), ragged lane lengths,
//!   mid-batch session completion, and page-boundary crossings;
//! - the paged-KV lifecycle (reuse / truncate / evict) against a dense
//!   mirror, mirroring `native_decode.rs`'s cache-lifecycle pins;
//! - peak page-pool bytes track live context, not `sessions × max_seq`;
//! - the batched serving backend (`decode_step_sessions` chunked to the
//!   session cap) matches the sequential sliding reference under
//!   interleaving and eviction;
//! - threading is invisible to the math: a `step_batch` run on a 2/4/7
//!   wide worker pool (widths chosen to NOT divide the row counts) emits
//!   the same logit bits as the single-threaded run, and the pool itself
//!   parks/wakes across many scopes, joins cleanly on drop, and rejects
//!   nested scopes.

use nmsparse::coordinator::server::{NativeBackend, ReplicaBackend, StepOutcome};
use nmsparse::engine::{
    window_start, EngineConfig, NativeEngine, NativeSparsity, SessionKvPool, StepBatch, WorkerPool,
};
use nmsparse::sparsity::Pattern;
use nmsparse::util::miniprop::{forall_simple, Config};
use nmsparse::util::prng::Rng;

fn test_cfg(max_seq: usize) -> EngineConfig {
    EngineConfig {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq,
    }
}

#[test]
fn prop_step_batch_bitwise_identical_to_sequential_steps() {
    // Random lane counts, patterns, page sizes and ragged per-lane
    // prompts; lanes complete mid-run (drop out at different steps).
    // After every batched step, each live lane's logits must equal the
    // sequential engine's bit-for-bit.
    let cfg = Config { cases: 18, ..Config::default() };
    let pats = [
        Pattern::Dense,
        Pattern::NM { n: 2, m: 4 },
        Pattern::NM { n: 8, m: 16 },
        Pattern::NM { n: 16, m: 32 },
    ];
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = pats[rng.range(0, pats.len())];
            let seed = rng.next_u64();
            let lanes = rng.range(1, 6);
            let page_tokens = rng.range(1, 7); // tiny pages: boundary-heavy
            // Ragged prompts + ragged step budgets => mid-batch dropout.
            let prompts: Vec<Vec<u32>> = (0..lanes)
                .map(|_| {
                    let len = rng.range(1, 9);
                    (0..len).map(|_| rng.range(0, 48) as u32).collect()
                })
                .collect();
            let budgets: Vec<usize> = (0..lanes).map(|_| rng.range(1, 10)).collect();
            (pattern, seed, page_tokens, prompts, budgets)
        },
        |(pattern, seed, page_tokens, prompts, budgets)| {
            let ecfg = test_cfg(24);
            let mk = || {
                NativeEngine::synthetic(&ecfg, *seed, NativeSparsity::act(*pattern)).unwrap()
            };
            let lanes = prompts.len();
            // Batched world: one engine, one SessionKvPool, one plan.
            let mut be = mk();
            let mut bpool = be.new_kv_pool_with(*page_tokens);
            let mut sessions = SessionKvPool::new(lanes);
            let mut batch = StepBatch::new();
            let mut brows: Vec<Vec<u32>> = prompts.clone();
            // Sequential world: same-seed engine, per-lane caches.
            let mut se = mk();
            let mut spool = se.new_kv_pool_with(*page_tokens);
            let mut srows: Vec<Vec<u32>> = prompts.clone();
            let mut skvs: Vec<_> = (0..lanes).map(|_| spool.new_cache()).collect();
            // Total steps per lane: prefill the prompt, then decode to
            // the lane's budget; lanes drop out as budgets exhaust.
            let total: Vec<usize> =
                prompts.iter().zip(budgets).map(|(p, b)| p.len() + b - 1).collect();
            let mut fed = vec![0usize; lanes];
            for _ in 0..*total.iter().max().unwrap() {
                batch.clear();
                let mut stepped: Vec<usize> = Vec::new();
                for i in 0..lanes {
                    if fed[i] < total[i] {
                        batch.push(i as u64 + 1, brows[i][fed[i]]);
                        stepped.push(i);
                    }
                }
                if batch.is_empty() {
                    break;
                }
                for i in 0..lanes {
                    sessions.get_or_create(&mut bpool, i as u64 + 1);
                }
                be.step_batch(&mut batch, &mut sessions, &mut bpool).unwrap();
                for (lane, &i) in stepped.iter().enumerate() {
                    // Sequential twin steps the same token.
                    se.step(&mut skvs[i], &mut spool, srows[i][fed[i]]).unwrap();
                    let want: Vec<u32> = se.logits().iter().map(|v| v.to_bits()).collect();
                    let got: Vec<u32> = batch.logits(lane).iter().map(|v| v.to_bits()).collect();
                    if got != want {
                        return false;
                    }
                    fed[i] += 1;
                    // Past the prompt, extend both rows greedily (same
                    // logits => same argmax).
                    if fed[i] == brows[i].len() && fed[i] < total[i] {
                        let tok = batch.argmax(lane);
                        brows[i].push(tok);
                        srows[i].push(tok);
                    }
                }
            }
            fed.iter().zip(&total).all(|(f, t)| f == t)
        },
    );
}

#[test]
fn step_batch_validates_lanes() {
    let ecfg = test_cfg(8);
    let mut e = NativeEngine::synthetic(&ecfg, 3, NativeSparsity::act(Pattern::NM { n: 2, m: 4 }))
        .unwrap();
    let mut pool = e.new_kv_pool_with(2);
    let mut sessions = SessionKvPool::new(4);
    let mut batch = StepBatch::new();
    // Empty batch is a no-op.
    e.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
    // Non-resident session errors.
    batch.push(7, 1);
    assert!(e.step_batch(&mut batch, &mut sessions, &mut pool).is_err());
    sessions.get_or_create(&mut pool, 7);
    e.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
    // Duplicate session ids error.
    batch.clear();
    batch.push(7, 1);
    batch.push(7, 2);
    assert!(e.step_batch(&mut batch, &mut sessions, &mut pool).is_err());
    // Out-of-vocab token errors.
    batch.clear();
    batch.push(7, 999);
    assert!(e.step_batch(&mut batch, &mut sessions, &mut pool).is_err());
    // Full cache errors (max_seq 8).
    batch.clear();
    batch.push(7, 1);
    for _ in 0..7 {
        e.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
    }
    assert!(sessions.get_mut(7).unwrap().kv.is_full());
    assert!(e.step_batch(&mut batch, &mut sessions, &mut pool).is_err());
}

#[test]
fn prop_paged_kv_lifecycle_against_dense_mirror() {
    // Random interleavings of step/truncate/reset across two cache
    // handles sharing one pool: logits after every operation sequence
    // must match a fresh-prefill reference, and page accounting must
    // never leak.
    let cfg = Config { cases: 16, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let seed = rng.next_u64();
            let page_tokens = rng.range(1, 5);
            let ops: Vec<(u8, usize)> = (0..rng.range(3, 12))
                .map(|_| (rng.range(0, 3) as u8, rng.range(0, 10)))
                .collect();
            (seed, page_tokens, ops)
        },
        |(seed, page_tokens, ops)| {
            let ecfg = test_cfg(12);
            let pattern = Pattern::NM { n: 8, m: 16 };
            let mut e = NativeEngine::synthetic(&ecfg, *seed, NativeSparsity::act(pattern))
                .unwrap();
            let mut pool = e.new_kv_pool_with(*page_tokens);
            let mut kvs = [pool.new_cache(), pool.new_cache()];
            // The dense mirror: the token prefix each cache must be
            // equivalent to.
            let mut mirror: [Vec<u32>; 2] = [Vec::new(), Vec::new()];
            for (i, (op, arg)) in ops.iter().enumerate() {
                let which = i % 2;
                match op {
                    0 => {
                        // Step one token (skip when full).
                        if mirror[which].len() < ecfg.max_seq {
                            let tok = (*arg % ecfg.vocab) as u32;
                            e.step(&mut kvs[which], &mut pool, tok).unwrap();
                            mirror[which].push(tok);
                        }
                    }
                    1 => {
                        let cut = *arg % (mirror[which].len() + 1);
                        kvs[which].truncate(&mut pool, cut);
                        mirror[which].truncate(cut);
                    }
                    _ => {
                        kvs[which].reset(&mut pool);
                        mirror[which].clear();
                    }
                }
                // Invariants: length sync + page accounting.
                if kvs[which].len() != mirror[which].len() {
                    return false;
                }
                let want_pages = mirror[which].len().div_ceil(*page_tokens);
                if kvs[which].pages_held() < want_pages {
                    return false;
                }
                let held: usize = kvs.iter().map(|k| k.pages_held()).sum();
                if pool.outstanding_pages() != held {
                    return false;
                }
            }
            // Equivalence: stepping one more token on the survivor must
            // match a fresh prefill of mirror + token.
            for which in 0..2 {
                if mirror[which].len() >= ecfg.max_seq {
                    continue;
                }
                e.step(&mut kvs[which], &mut pool, 5).unwrap();
                let got: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
                let mut fresh = pool.new_cache();
                let mut row = mirror[which].clone();
                row.push(5);
                e.prefill(&mut fresh, &mut pool, &row).unwrap();
                let want: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
                fresh.reset(&mut pool);
                kvs[which].truncate(&mut pool, mirror[which].len());
                if got != want {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn peak_kv_bytes_track_live_context_not_session_count() {
    // The acceptance criterion: many short sessions must not pin
    // sessions × max_seq bytes. 12 sessions × 4-token contexts on a
    // max_seq-64 engine: peak paged bytes stay far below the pinned
    // equivalent.
    let ecfg = EngineConfig::tiny(); // max_seq 64
    let pattern = Pattern::NM { n: 8, m: 16 };
    let sessions_n = 12usize;
    let mut backend =
        NativeBackend::synthetic(&ecfg, 17, NativeSparsity::act(pattern), vec![], sessions_n)
            .unwrap()
            .with_page_tokens(8);
    let rows: Vec<Vec<u32>> = (0..sessions_n)
        .map(|i| (0..4).map(|t| ((i * 7 + t) % 40) as u32).collect())
        .collect();
    let live: Vec<(u64, &[u32])> =
        rows.iter().enumerate().map(|(i, r)| (i as u64 + 1, r.as_slice())).collect();
    let outs = backend.decode_step_sessions(&live).unwrap();
    assert!(outs.iter().all(|o| o.token().is_some()));
    let pages = backend.pages();
    // 4 fed positions per session => 1 page of 8 each; the pinned
    // design held ceil(64/8) = 8 pages per session.
    let paged_peak = pages.peak_bytes();
    let pinned = sessions_n * ecfg.max_seq.div_ceil(8) * pages.page_bytes();
    assert!(
        paged_peak * 4 <= pinned,
        "peak {paged_peak} bytes not ≪ pinned {pinned} bytes"
    );
    // And the pool actually recycles: ending sessions returns every page.
    for i in 0..sessions_n {
        backend.end_session(i as u64 + 1);
    }
    assert_eq!(backend.pages().outstanding_pages(), 0);
}

#[test]
fn prop_batched_backend_matches_sliding_reference_under_eviction() {
    // The serving-path property: random session caps (forcing chunked
    // batches + LRU eviction), page sizes, ragged prompts and budgets —
    // the batched backend's per-session outputs must equal the
    // sequential sliding reference, even as rows outgrow the context.
    let cfg = Config { cases: 12, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let seed = rng.next_u64();
            let lanes = rng.range(2, 5);
            let cap = rng.range(1, lanes + 2);
            let page_tokens = rng.range(2, 6);
            let prompts: Vec<Vec<u32>> = (0..lanes)
                .map(|_| {
                    let len = rng.range(1, 20); // may exceed max_seq 16
                    (0..len).map(|_| rng.range(0, 48) as u32).collect()
                })
                .collect();
            let max_new = rng.range(2, 8);
            (seed, cap, page_tokens, prompts, max_new)
        },
        |(seed, cap, page_tokens, prompts, max_new)| {
            let ecfg = test_cfg(16);
            let pattern = Pattern::NM { n: 8, m: 16 };
            let lanes = prompts.len();
            let mut backend =
                NativeBackend::synthetic(&ecfg, *seed, NativeSparsity::act(pattern), vec![], 8)
                    .unwrap()
                    .with_session_cap(*cap)
                    .with_page_tokens(*page_tokens);
            let mut engine =
                NativeEngine::synthetic(&ecfg, *seed, NativeSparsity::act(pattern)).unwrap();
            let mut pool = engine.new_kv_pool_with(*page_tokens);
            let mut kv = pool.new_cache();
            let want: Vec<Vec<u32>> = prompts
                .iter()
                .map(|p| {
                    engine.generate_greedy_sliding(&mut kv, &mut pool, p, *max_new, &[]).unwrap()
                })
                .collect();
            let mut rows = prompts.clone();
            let mut got: Vec<Vec<u32>> = vec![Vec::new(); lanes];
            let mut done = vec![false; lanes];
            loop {
                let ids: Vec<usize> = (0..lanes).filter(|i| !done[*i]).collect();
                if ids.is_empty() {
                    break;
                }
                let live: Vec<(u64, &[u32])> =
                    ids.iter().map(|i| (*i as u64 + 1, rows[*i].as_slice())).collect();
                let outs = backend.decode_step_sessions(&live).unwrap();
                for (i, out) in ids.into_iter().zip(outs) {
                    let StepOutcome::Token(tok) = out else { return false };
                    got[i].push(tok);
                    rows[i].push(tok);
                    if got[i].len() >= *max_new {
                        done[i] = true;
                    }
                }
            }
            got == want
        },
    );
}

#[test]
fn re_ticking_an_unchanged_row_re_emits_instead_of_ending() {
    // A caller that repeats a tick without appending the emitted token
    // (idempotent retry) must get the same token again — never a
    // session-ending None. The reconcile rebuilds the window and
    // re-emits; the incremental path still applies once the row grows.
    let ecfg = test_cfg(16);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut backend = NativeBackend::synthetic(&ecfg, 23, NativeSparsity::act(pattern), vec![], 4)
        .unwrap()
        .with_page_tokens(4);
    for len in [3usize, 16, 21] {
        let id = len as u64;
        let row: Vec<u32> = (0..len as u32).map(|i| i % 40).collect();
        let first = backend.decode_step_sessions(&[(id, row.as_slice())]).unwrap()[0];
        let again = backend.decode_step_sessions(&[(id, row.as_slice())]).unwrap()[0];
        assert_eq!(first, again, "len={len}");
        assert!(first.token().is_some(), "len={len}");
        // Normal continuation after the re-tick: one incremental step.
        let mut grown = row.clone();
        grown.push(first.token().unwrap());
        let steps_before = backend.engine().stats().steps;
        let next = backend.decode_step_sessions(&[(id, grown.as_slice())]).unwrap()[0];
        assert!(next.token().is_some(), "len={len}");
        let fed = backend.engine().stats().steps - steps_before;
        if grown.len() <= ecfg.max_seq {
            assert_eq!(fed, 1, "len={len}: incremental path lost after re-tick");
        }
        backend.end_session(id);
    }
}

#[test]
fn prop_threaded_step_batch_bitwise_identical_to_single_threaded() {
    // The tentpole's core claim: the worker pool changes wall time,
    // never bits. Replay the same batched decode (ragged prompts, ragged
    // budgets, greedy extension, tiny pages) on pools of width 1/2/4/7 —
    // 7 divides none of vocab 48, d_model 32, ffn 64, so every width
    // exercises uneven row-range partitions — and require the full
    // per-tick logit-bit trace to be identical across widths.
    let cfg = Config { cases: 10, ..Config::default() };
    let pats = [
        Pattern::Dense,
        Pattern::NM { n: 2, m: 4 },
        Pattern::NM { n: 8, m: 16 },
        Pattern::NM { n: 16, m: 32 },
    ];
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = pats[rng.range(0, pats.len())];
            let seed = rng.next_u64();
            let lanes = rng.range(1, 6);
            let page_tokens = rng.range(1, 7);
            let prompts: Vec<Vec<u32>> = (0..lanes)
                .map(|_| {
                    let len = rng.range(1, 9);
                    (0..len).map(|_| rng.range(0, 48) as u32).collect()
                })
                .collect();
            let budgets: Vec<usize> = (0..lanes).map(|_| rng.range(1, 8)).collect();
            (pattern, seed, page_tokens, prompts, budgets)
        },
        |(pattern, seed, page_tokens, prompts, budgets)| {
            let ecfg = test_cfg(24);
            let lanes = prompts.len();
            let total: Vec<usize> =
                prompts.iter().zip(budgets).map(|(p, b)| p.len() + b - 1).collect();
            // One full batched decode at a given pool width; returns the
            // concatenated per-tick logit bits of every live lane.
            let run = |threads: usize| -> Vec<Vec<u32>> {
                let mut e =
                    NativeEngine::synthetic(&ecfg, *seed, NativeSparsity::act(*pattern))
                        .unwrap()
                        .with_threads(threads);
                let mut pool = e.new_kv_pool_with(*page_tokens);
                let mut sessions = SessionKvPool::new(lanes);
                let mut batch = StepBatch::new();
                let mut rows: Vec<Vec<u32>> = prompts.clone();
                let mut fed = vec![0usize; lanes];
                let mut trace: Vec<Vec<u32>> = Vec::new();
                loop {
                    batch.clear();
                    let mut stepped: Vec<usize> = Vec::new();
                    for i in 0..lanes {
                        if fed[i] < total[i] {
                            batch.push(i as u64 + 1, rows[i][fed[i]]);
                            stepped.push(i);
                        }
                    }
                    if batch.is_empty() {
                        break;
                    }
                    for &i in &stepped {
                        sessions.get_or_create(&mut pool, i as u64 + 1);
                    }
                    e.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
                    for (lane, &i) in stepped.iter().enumerate() {
                        trace.push(batch.logits(lane).iter().map(|v| v.to_bits()).collect());
                        fed[i] += 1;
                        if fed[i] == rows[i].len() && fed[i] < total[i] {
                            let tok = batch.argmax(lane);
                            rows[i].push(tok);
                        }
                    }
                }
                trace
            };
            let base = run(1);
            !base.is_empty() && [2usize, 4, 7].iter().all(|&t| run(t) == base)
        },
    );
}

#[test]
fn worker_pool_parks_wakes_and_reuses_across_many_scopes() {
    // One spawn, many ticks: the engine-lifetime usage pattern. Workers
    // park between scopes; every scope must still run every part exactly
    // once (the counter is exact, not ≥).
    use std::sync::atomic::{AtomicUsize, Ordering};
    let pool = WorkerPool::new(4);
    assert_eq!(pool.threads(), 4);
    let hits = AtomicUsize::new(0);
    for round in 0..100 {
        let parts = 1 + round % 9; // exercises the parts==1 inline path too
        pool.run(parts, |_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
    }
    let want: usize = (0..100).map(|r| 1 + r % 9).sum();
    assert_eq!(hits.load(Ordering::Relaxed), want);
}

#[test]
fn worker_pool_drop_joins_cleanly_after_use() {
    // Dropping a pool mid-lifetime (engine teardown) must join, not hang
    // or leak parked threads — at widths below, at, and above the part
    // count, used or never used.
    use std::sync::atomic::{AtomicUsize, Ordering};
    for threads in [1usize, 2, 5] {
        let pool = WorkerPool::new(threads);
        let sum = AtomicUsize::new(0);
        pool.run_ranges(33, |lo, hi| {
            sum.fetch_add(hi - lo, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 33, "threads={threads}");
        drop(pool);
        let unused = WorkerPool::new(threads);
        drop(unused); // never ran a scope: workers still parked
    }
}

#[test]
#[should_panic(expected = "nested WorkerPool scope")]
fn worker_pool_rejects_nested_scopes_from_integration_surface() {
    // Kernels partition once at the top; a part that re-enters the pool
    // would deadlock against its own scope, so it panics instead.
    // (parts == 1 runs inline, so the rejection fires on this thread and
    // the original panic message propagates.)
    let pool = WorkerPool::new(2);
    pool.run(1, |_| pool.run(1, |_| {}));
}

#[test]
fn window_rule_is_stateless_and_page_aligned() {
    for (row_len, max_seq, pt, want) in [
        (5usize, 16usize, 4usize, 0usize),
        (16, 16, 4, 0),
        (17, 16, 4, 4),
        (20, 16, 4, 4),
        (21, 16, 4, 8),
        (17, 16, 1, 1),
        (40, 16, 16, 32),
    ] {
        assert_eq!(window_start(row_len, max_seq, pt), want, "({row_len},{max_seq},{pt})");
    }
}
