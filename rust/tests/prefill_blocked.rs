//! Property suite for blocked prefill (DESIGN.md §2.13) and the
//! resumable bounded-block serving prefill built on it:
//!
//! - `prefill_blocked` is **bitwise logits-identical** to the per-token
//!   prefill loop across patterns (2:4 / 8:16 / 16:32 / dense), block
//!   sizes (1, 3, a full page, larger than the prompt), and prompt
//!   lengths that straddle page boundaries — and leaves identical KV
//!   state (length, pages held) and identical `DecodeStats`;
//! - `generate_greedy_with_block` emits the same tokens as
//!   `generate_greedy` at every block size, including left-cropped long
//!   prompts;
//! - a `NativeBackend` with a prefill budget emits `Pending` while a
//!   long prompt ingests block-by-block, then the same token stream as
//!   the unbudgeted backend and the sequential sliding oracle — feeding
//!   each prompt position exactly once (steps parity);
//! - short-decode sessions advance in the same ticks a long prefill is
//!   still `Pending` (continuous batching);
//! - a tick wider than the session cap (slot eviction mid-tick would
//!   reset in-flight prefills forever) falls back to feed-to-completion
//!   and still matches the oracle.

use nmsparse::coordinator::server::{NativeBackend, ReplicaBackend, StepOutcome};
use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::sparsity::Pattern;

fn test_cfg(max_seq: usize) -> EngineConfig {
    EngineConfig {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq,
    }
}

fn prompt_of(len: usize) -> Vec<u32> {
    (0..len).map(|i| ((i * 5 + 3) % 40) as u32).collect()
}

const PATTERNS: [Pattern; 4] = [
    Pattern::Dense,
    Pattern::NM { n: 2, m: 4 },
    Pattern::NM { n: 8, m: 16 },
    Pattern::NM { n: 16, m: 32 },
];

#[test]
fn blocked_prefill_bitwise_identical_across_patterns_blocks_and_pages() {
    for pattern in PATTERNS {
        let ecfg = test_cfg(16);
        let mut engine = NativeEngine::synthetic(&ecfg, 11, NativeSparsity::act(pattern)).unwrap();
        for page_tokens in [3usize, 5] {
            let mut pool = engine.new_kv_pool_with(page_tokens);
            // Prompt lengths below, at, just past, and far past a page
            // boundary, plus the full context.
            for len in [1usize, 2, page_tokens, page_tokens + 1, 2 * page_tokens + 3, 16] {
                let prompt = prompt_of(len);
                engine.reset_stats();
                let mut kv_ref = pool.new_cache();
                engine.prefill(&mut kv_ref, &mut pool, &prompt).unwrap();
                let want: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
                let want_stats = engine.stats();
                // Block 1 (degenerate), 3 (straddles pages), a full page,
                // and larger than the whole prompt (single chunk).
                for block in [1usize, 3, page_tokens, len + 7] {
                    engine.reset_stats();
                    let mut kv = pool.new_cache();
                    engine.prefill_blocked(&mut kv, &mut pool, &prompt, block).unwrap();
                    let got: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
                    let label = format!("{pattern} pt={page_tokens} len={len} block={block}");
                    assert_eq!(got, want, "{label}: logits diverged");
                    assert_eq!(kv.len(), kv_ref.len(), "{label}: kv length");
                    assert_eq!(kv.pages_held(), kv_ref.pages_held(), "{label}: pages held");
                    assert_eq!(engine.stats(), want_stats, "{label}: stats diverged");
                    kv.reset(&mut pool);
                }
                kv_ref.reset(&mut pool);
            }
        }
    }
}

#[test]
fn generate_with_block_matches_per_token_generation() {
    let ecfg = test_cfg(24);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut engine = NativeEngine::synthetic(&ecfg, 5, NativeSparsity::act(pattern)).unwrap();
    let mut pool = engine.new_kv_pool_with(4);
    let mut kv = pool.new_cache();
    // Short, page-straddling, and beyond-max_seq (left-cropped) prompts.
    for len in [2usize, 9, 24, 40] {
        let prompt = prompt_of(len);
        let want = engine.generate_greedy(&mut kv, &mut pool, &prompt, 8, &[]).unwrap();
        for block in [1usize, 4, 16] {
            let got = engine
                .generate_greedy_with_block(&mut kv, &mut pool, &prompt, 8, &[], block)
                .unwrap();
            assert_eq!(got, want, "len={len} block={block}");
        }
    }
}

#[test]
fn blocked_prefill_rejects_overflow_and_bad_tokens() {
    let ecfg = test_cfg(8);
    let mut engine =
        NativeEngine::synthetic(&ecfg, 3, NativeSparsity::act(Pattern::NM { n: 2, m: 4 })).unwrap();
    let mut pool = engine.new_kv_pool_with(4);
    let mut kv = pool.new_cache();
    // A prompt past the KV capacity fails up-front, before any chunk ran.
    let err = engine.prefill_blocked(&mut kv, &mut pool, &prompt_of(10), 4).unwrap_err();
    assert!(err.to_string().contains("overflows"), "{err}");
    assert_eq!(kv.len(), 0, "failed prefill must not advance the cache");
    // An out-of-vocabulary token fails up-front too.
    let err = engine.prefill_blocked(&mut kv, &mut pool, &[1, 2, 48, 3], 2).unwrap_err();
    assert!(err.to_string().contains("vocabulary"), "{err}");
    assert_eq!(kv.len(), 0);
}

/// Drive one backend session to `max_new` tokens, collecting outcomes.
/// Returns (tokens, pending_ticks).
fn drive_session(
    backend: &mut NativeBackend,
    id: u64,
    prompt: &[u32],
    max_new: usize,
) -> (Vec<u32>, usize) {
    let mut row = prompt.to_vec();
    let mut out = Vec::new();
    let mut pending = 0usize;
    // Generous tick bound: every prompt position plus every token.
    for _ in 0..(prompt.len() + max_new + 4) {
        if out.len() >= max_new {
            break;
        }
        match backend.decode_step_sessions(&[(id, row.as_slice())]).unwrap()[0] {
            StepOutcome::Token(tok) => {
                out.push(tok);
                row.push(tok);
            }
            StepOutcome::Pending => pending += 1,
            StepOutcome::End => panic!("session ended unexpectedly"),
        }
    }
    backend.end_session(id);
    (out, pending)
}

#[test]
fn bounded_prefill_emits_pending_then_matches_oracle_and_feeds_once() {
    let ecfg = test_cfg(16);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let max_new = 6;
    // Prompts inside the window and beyond it (sliding-window crop).
    for len in [11usize, 14, 21] {
        let prompt = prompt_of(len);
        let mut oracle_engine =
            NativeEngine::synthetic(&ecfg, 7, NativeSparsity::act(pattern)).unwrap();
        let mut pool = oracle_engine.new_kv_pool_with(4);
        let mut kv = pool.new_cache();
        let want = oracle_engine
            .generate_greedy_sliding(&mut kv, &mut pool, &prompt, max_new, &[])
            .unwrap();

        let mut legacy = NativeBackend::synthetic(&ecfg, 7, NativeSparsity::act(pattern), vec![], 4)
            .unwrap()
            .with_page_tokens(4);
        let (legacy_toks, legacy_pending) = drive_session(&mut legacy, 1, &prompt, max_new);
        assert_eq!(legacy_toks, want, "len={len}: legacy backend vs sliding oracle");
        assert_eq!(legacy_pending, 0, "len={len}: feed-to-completion never defers");

        let mut bounded =
            NativeBackend::synthetic(&ecfg, 7, NativeSparsity::act(pattern), vec![], 4)
                .unwrap()
                .with_page_tokens(4)
                .with_prefill_block(2);
        let (bounded_toks, bounded_pending) = drive_session(&mut bounded, 1, &prompt, max_new);
        assert_eq!(bounded_toks, want, "len={len}: bounded backend vs sliding oracle");
        // The windowed prompt has window_len - 1 body positions to feed in
        // blocks of 2, minus nothing on the emitting tick: > 2 body
        // positions guarantees at least one deferred tick.
        assert!(bounded_pending >= 1, "len={len}: bounded prefill never deferred");
        // Feeding each position exactly once: the budgeted path consumed
        // the same number of engine steps as feed-to-completion.
        assert_eq!(
            bounded.engine().stats().steps,
            legacy.engine().stats().steps,
            "len={len}: bounded prefill re-fed positions"
        );
    }
}

#[test]
fn short_decodes_advance_while_long_prefill_is_pending() {
    let ecfg = test_cfg(16);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let max_new = 5;
    let long = prompt_of(14);
    let short = prompt_of(3);
    // Per-session references from the unbudgeted backend.
    let mut reference =
        NativeBackend::synthetic(&ecfg, 13, NativeSparsity::act(pattern), vec![], 4)
            .unwrap()
            .with_page_tokens(4);
    let (want_long, _) = drive_session(&mut reference, 1, &long, max_new);
    let (want_short, _) = drive_session(&mut reference, 2, &short, max_new);

    let mut backend = NativeBackend::synthetic(&ecfg, 13, NativeSparsity::act(pattern), vec![], 4)
        .unwrap()
        .with_page_tokens(4)
        .with_prefill_block(2);
    let mut rows = [long.clone(), short.clone()];
    let mut outs: [Vec<u32>; 2] = [Vec::new(), Vec::new()];
    let mut overlapped = false;
    for _ in 0..(long.len() + 2 * max_new + 4) {
        if outs[0].len() >= max_new && outs[1].len() >= max_new {
            break;
        }
        let live: Vec<(u64, &[u32])> = (0..2)
            .filter(|&i| outs[i].len() < max_new)
            .map(|i| (i as u64 + 1, rows[i].as_slice()))
            .collect();
        let ids: Vec<usize> = (0..2).filter(|&i| outs[i].len() < max_new).collect();
        let step = backend.decode_step_sessions(&live).unwrap();
        // The continuous-batching claim: the short session takes a token
        // in a tick where the long prompt is still ingesting.
        if ids.len() == 2
            && step[0] == StepOutcome::Pending
            && matches!(step[1], StepOutcome::Token(_))
        {
            overlapped = true;
        }
        for (i, out) in ids.into_iter().zip(step) {
            if let StepOutcome::Token(tok) = out {
                outs[i].push(tok);
                rows[i].push(tok);
            }
        }
    }
    assert!(overlapped, "short decode never advanced during the long prefill");
    assert_eq!(outs[0], want_long, "long session diverged from the unbudgeted backend");
    assert_eq!(outs[1], want_short, "short session diverged from the unbudgeted backend");
}

#[test]
fn tick_wider_than_session_cap_falls_back_to_feed_to_completion() {
    // At cap 1 a 2-row tick chunk-evicts slots within the tick; a bounded
    // block per tick would reset the other session's in-flight prefill
    // forever. The backend detects this and feeds to completion instead:
    // every lane emits a token on the first tick, and tokens match the
    // unbudgeted cap-1 backend exactly.
    let ecfg = test_cfg(16);
    let pattern = Pattern::NM { n: 2, m: 4 };
    let max_new = 4;
    let prompts = [prompt_of(9), prompt_of(6)];

    let mut reference =
        NativeBackend::synthetic(&ecfg, 19, NativeSparsity::act(pattern), vec![], 4)
            .unwrap()
            .with_session_cap(1)
            .with_page_tokens(4);
    let mut bounded = NativeBackend::synthetic(&ecfg, 19, NativeSparsity::act(pattern), vec![], 4)
        .unwrap()
        .with_session_cap(1)
        .with_page_tokens(4)
        .with_prefill_block(2);

    for backend in [&mut reference, &mut bounded] {
        let live: Vec<(u64, &[u32])> =
            prompts.iter().enumerate().map(|(i, p)| (i as u64 + 1, p.as_slice())).collect();
        let first = backend.decode_step_sessions(&live).unwrap();
        assert!(
            first.iter().all(|o| o.token().is_some()),
            "cap-1 wide tick must emit on the first tick (got {first:?})"
        );
    }

    // And full streams agree between the two backends.
    for (i, p) in prompts.iter().enumerate() {
        let (want, _) = drive_session(&mut reference, 10 + i as u64, p, max_new);
        let (got, _) = drive_session(&mut bounded, 10 + i as u64, p, max_new);
        assert_eq!(got, want, "lane {i} diverged under the cap-1 fallback");
    }
}
