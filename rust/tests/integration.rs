//! Integration tests over the full runtime: PJRT + artifacts + coordinator.
//! All tests skip (with a note) when `make artifacts` has not run, so
//! `cargo test` stays green on a fresh checkout; `make test` runs them for
//! real. Single #[test] wrapper to share one PJRT client/process.

use nmsparse::coordinator::methods::{MethodConfig, WeightTransform};
use nmsparse::coordinator::Coordinator;
use nmsparse::sparsity::Pattern;
use nmsparse::synthlang::corpus::Corpus;
use nmsparse::synthlang::tasks::TaskSet;
use nmsparse::synthlang::vocab::Vocab;
use std::path::Path;

fn artifacts_ready() -> bool {
    Path::new("artifacts/io_manifest.json").exists()
}

#[test]
fn runtime_end_to_end() {
    if !artifacts_ready() {
        eprintln!("artifacts missing — run `make artifacts`; skipping integration tests");
        return;
    }
    let coord = Coordinator::open(Path::new("artifacts")).expect("open");
    let dims = coord.pool.manifest.dims.clone();

    // --- 1. dense engine runs and produces sane logprobs ---
    let dense = MethodConfig::dense();
    let engine = coord.pool.engine(&dense).expect("dense engine");
    let tokens: Vec<i32> = (0..dims.batch * dims.seq).map(|i| (i % 90) as i32).collect();
    let lens = vec![dims.seq as i32; dims.batch];
    let out = engine.run(&coord.pool.rt, &tokens, &lens).expect("run");
    assert!(out.tgt_logprobs.iter().all(|x| x.is_finite() && *x <= 1e-4));
    assert!(out.last_logits.iter().all(|x| x.is_finite()));

    // --- 2. sparsification with every site disabled == dense ---
    let p24 = Pattern::NM { n: 2, m: 4 };
    let disabled = MethodConfig::act(p24)
        .with_disabled_sites(&["q", "k", "v", "o", "gate", "up", "down"]);
    let e_dis = coord.pool.engine(&disabled).expect("disabled engine");
    let out_dis = e_dis.run(&coord.pool.rt, &tokens, &lens).expect("run");
    let max_diff = out
        .tgt_logprobs
        .iter()
        .zip(&out_dis.tgt_logprobs)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 1e-3, "enable plumbing broken: {max_diff}");

    // --- 3. sparsification actually changes outputs when enabled ---
    let e_24 = coord.pool.engine(&MethodConfig::act(p24)).expect("2:4");
    let out_24 = e_24.run(&coord.pool.rt, &tokens, &lens).expect("run");
    let diff = out
        .tgt_logprobs
        .iter()
        .zip(&out_24.tgt_logprobs)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(diff > 1e-4, "2:4 sparsification had no effect");

    // --- 4. trained model: dense ppl sane and ordered vs sparse ---
    let stream = Corpus::read_tokens(Path::new("artifacts/data/corpus_valid.tokens")).unwrap();
    let ppl_dense = coord.perplexity(&dense, &stream, 8).unwrap();
    let ppl_24 = coord.perplexity(&MethodConfig::act(p24), &stream, 8).unwrap();
    assert!(ppl_dense > 1.0 && ppl_dense < 50.0, "dense ppl {ppl_dense}");
    assert!(
        ppl_24 > ppl_dense * 0.99,
        "2:4 ppl {ppl_24} should not beat dense {ppl_dense}"
    );

    // --- 5. scoring determinism + batch-composition independence ---
    let vocab = Vocab::synthlang();
    let q = vocab.encode("does the red fox live in the forest ?").unwrap();
    let yes = vocab.encode("yes").unwrap();
    let mut row = q.clone();
    let start = row.len();
    row.extend(&yes);
    let single = vec![(row.clone(), (start, start + 1))];
    let s1 = coord.score_rows(&dense, &single).unwrap();
    let s2 = coord.score_rows(&dense, &single).unwrap();
    assert_eq!(s1, s2, "scoring must be deterministic");
    // Same row inside a larger batch gets the same score.
    let mut many = vec![(row.clone(), (start, start + 1))];
    for i in 0..9u32 {
        let filler = vocab.encode("the red fox eats berries .").unwrap();
        let fl = filler.len();
        let _ = i;
        many.push((filler, (fl - 1, fl)));
    }
    let s3 = coord.score_rows(&dense, &many).unwrap();
    assert!(
        (s1[0] - s3[0]).abs() < 1e-4,
        "batch composition changed a score: {} vs {}",
        s1[0],
        s3[0]
    );

    // --- 6. weight transforms flow through the dense artifact ---
    let wt = MethodConfig::wt(Pattern::Unstructured { keep_pct: 50 });
    assert_eq!(wt.weight_transform, WeightTransform::Prune(Pattern::Unstructured { keep_pct: 50 }));
    let s_wt = coord.score_rows(&wt, &single).unwrap();
    assert!((s_wt[0] - s1[0]).abs() > 1e-6, "WT pruning had no effect");

    // --- 7. every manifest variant compiles, binds and runs ---
    let keys: Vec<String> = coord.pool.manifest.variants.keys().cloned().collect();
    for key in &keys {
        let meta = coord.pool.manifest.variant(key).unwrap().clone();
        let cfg = match meta.rank {
            Some(r) => {
                let mut c = MethodConfig::act(Pattern::parse(&meta.pattern).unwrap());
                c.variant_key = key.clone();
                c.rank = Some(r);
                c.id = format!("smoke-{key}");
                c
            }
            None => {
                let mut c = MethodConfig::act(Pattern::parse(&meta.pattern).unwrap());
                c.variant_key = key.clone();
                c.id = format!("smoke-{key}");
                c
            }
        };
        let e = coord.pool.engine(&cfg).unwrap_or_else(|err| panic!("{key}: {err:#}"));
        let o = e.run(&coord.pool.rt, &tokens, &lens).unwrap();
        assert!(
            o.tgt_logprobs.iter().all(|x| x.is_finite()),
            "variant {key} produced non-finite logprobs"
        );
    }

    // --- 8. generation is deterministic and stops on stop tokens ---
    let prompt = vocab.encode("where does the red fox live ? in").unwrap();
    let stop = vec![vocab.id(".").unwrap()];
    let g1 = coord.generate(&dense, &[prompt.clone()], 8, &stop).unwrap();
    let g2 = coord.generate(&dense, &[prompt.clone()], 8, &stop).unwrap();
    assert_eq!(g1, g2, "greedy decode must be deterministic");
    assert!(!g1[0].is_empty());

    // --- 9. task evaluation above chance for the trained dense model ---
    let boolq = TaskSet::load(Path::new("artifacts/data/tasks/synth_boolq.json")).unwrap();
    let r = nmsparse::evalharness::eval_taskset(&coord, &dense, &boolq, 48).unwrap();
    assert!(
        r.accuracy > 0.55,
        "trained dense model should beat chance on boolq: {}",
        r.accuracy
    );

    println!(
        "integration OK: {} variants exercised, dense ppl {ppl_dense:.2}, boolq {:.3}",
        keys.len(),
        r.accuracy
    );
}
