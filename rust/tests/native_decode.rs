//! Decode-equivalence property suite for the native engine (no
//! artifacts, no PJRT): KV-cached incremental decode must be
//! token-identical to the full-context reference loop across patterns,
//! prompt shapes and stop-token placements, and must survive every cache
//! lifecycle edge — reset, truncation, LRU eviction, re-prefill, paged
//! sliding windows — plus the artifacts-format round trip through
//! `Coordinator`'s native path (including per-site S-PTS methodparams).
//! The batched `step_batch` twin of these properties lives in
//! `rust/tests/step_batch.rs`.

use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::coordinator::server::{NativeBackend, ReplicaBackend};
use nmsparse::coordinator::Coordinator;
use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::sparsity::Pattern;
use nmsparse::util::miniprop::{forall_simple, Config};
use nmsparse::util::prng::Rng;
use nmsparse::util::tensor::{Tensor, TensorStore};

fn test_cfg(max_seq: usize) -> EngineConfig {
    EngineConfig {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq,
    }
}

fn patterns() -> Vec<Pattern> {
    vec![
        Pattern::Dense,
        Pattern::NM { n: 2, m: 4 },
        Pattern::NM { n: 8, m: 16 },
        Pattern::NM { n: 16, m: 32 },
        Pattern::Unstructured { keep_pct: 50 },
    ]
}

#[test]
fn prop_kv_cached_decode_token_identical_to_full_context() {
    // The acceptance property: across patterns (2:4, 8:16, 16:32, dense,
    // u50), model seeds, prompt lengths, budgets and stop-token
    // placements, the KV-cached loop and the full-context loop emit the
    // same tokens.
    let cfg = Config { cases: 24, ..Config::default() };
    let pats = patterns();
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = *rng.choose(&pats);
            let seed = rng.next_u64();
            let plen = rng.range(1, 12);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.range(0, 48) as u32).collect();
            let max_new = rng.range(1, 14);
            // Half the cases pick stop tokens from the vocab (sometimes
            // hitting mid-generation), half run stop-free.
            let stops: Vec<u32> = if rng.chance(0.5) {
                (0..rng.range(1, 4)).map(|_| rng.range(0, 48) as u32).collect()
            } else {
                Vec::new()
            };
            (pattern, seed, prompt, max_new, stops)
        },
        |(pattern, seed, prompt, max_new, stops)| {
            let mut e =
                NativeEngine::synthetic(&test_cfg(32), *seed, NativeSparsity::act(*pattern))
                    .unwrap();
            let mut pool = e.new_kv_pool();
            let mut kv = pool.new_cache();
            let cached = e.generate_greedy(&mut kv, &mut pool, prompt, *max_new, stops).unwrap();
            let full =
                e.generate_greedy_full(&mut kv, &mut pool, prompt, *max_new, stops).unwrap();
            cached == full && !cached.is_empty() && cached.len() <= *max_new
        },
    );
}

#[test]
fn prop_stop_token_placement_truncates_identically() {
    // Take a free-running generation, pick each of its tokens as the stop
    // token in turn, and pin that both loops cut at exactly that point.
    let cfg = Config { cases: 10, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| (rng.next_u64(), rng.range(1, 6)),
        |(seed, plen)| {
            let pattern = Pattern::NM { n: 8, m: 16 };
            let mut e =
                NativeEngine::synthetic(&test_cfg(32), *seed, NativeSparsity::act(pattern))
                    .unwrap();
            let mut pool = e.new_kv_pool();
            let mut kv = pool.new_cache();
            let prompt: Vec<u32> = (0..*plen).map(|i| (i * 7 % 48) as u32).collect();
            let free = e.generate_greedy(&mut kv, &mut pool, &prompt, 8, &[]).unwrap();
            for (i, stop) in free.iter().enumerate() {
                let cached =
                    e.generate_greedy(&mut kv, &mut pool, &prompt, 8, &[*stop]).unwrap();
                let full =
                    e.generate_greedy_full(&mut kv, &mut pool, &prompt, 8, &[*stop]).unwrap();
                if cached != full {
                    return false;
                }
                // Cut at the first occurrence of the stop token.
                let first = free.iter().position(|t| t == stop).unwrap();
                if first <= i && cached != free[..=first].to_vec() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn cache_reuse_and_reset_are_stateless() {
    // One cache object reused (reset) across many prompts must match
    // fresh caches exactly.
    let pattern = Pattern::NM { n: 2, m: 4 };
    let mut e = NativeEngine::synthetic(&test_cfg(32), 11, NativeSparsity::act(pattern)).unwrap();
    let mut pool = e.new_kv_pool();
    let mut shared = pool.new_cache();
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![40, 41], vec![7; 10], vec![0]];
    let mut first = Vec::new();
    for p in &prompts {
        first.push(e.generate_greedy(&mut shared, &mut pool, p, 6, &[]).unwrap());
    }
    for (p, want) in prompts.iter().zip(&first) {
        let mut fresh = pool.new_cache();
        assert_eq!(&e.generate_greedy(&mut fresh, &mut pool, p, 6, &[]).unwrap(), want);
        fresh.reset(&mut pool);
    }
}

#[test]
fn truncate_rolls_back_to_identical_logits() {
    // Truncating the cache to a prefix and re-stepping must be
    // indistinguishable from prefilling that prefix fresh — including
    // cuts that release whole pages and cuts inside a page.
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut e = NativeEngine::synthetic(&test_cfg(32), 13, NativeSparsity::act(pattern)).unwrap();
    let mut pool = e.new_kv_pool_with(4);
    let row: Vec<u32> = (0..20).map(|i| (i * 5 % 48) as u32).collect();
    let mut kv = pool.new_cache();
    e.prefill(&mut kv, &mut pool, &row).unwrap();
    for cut in [1usize, 4, 7, 19] {
        kv.truncate(&mut pool, cut);
        assert!(kv.pages_held() <= cut.div_ceil(4).max(1), "pages recycled at cut={cut}");
        e.step(&mut kv, &mut pool, row[cut]).unwrap();
        let after_truncate: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
        let mut fresh = pool.new_cache();
        e.prefill(&mut fresh, &mut pool, &row[..cut + 1]).unwrap();
        let from_fresh: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
        assert_eq!(after_truncate, from_fresh, "cut={cut}");
        fresh.reset(&mut pool);
        // Restore for the next cut.
        kv.reset(&mut pool);
        e.prefill(&mut kv, &mut pool, &row).unwrap();
    }
}

#[test]
fn session_eviction_under_cap_one_is_token_identical() {
    // Two interleaved sessions on a cap-1 slot pool force an eviction and
    // a full window re-prefill on every step — tokens must not change.
    // This is the regression pin for the PR 4 eviction corner: the
    // backend reconciles anchors internally, no caller-side handling.
    let cfg = test_cfg(32);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let stop: Vec<u32> = vec![2];
    let mut backend =
        NativeBackend::synthetic(&cfg, 5, NativeSparsity::act(pattern), stop.clone(), 4)
            .unwrap()
            .with_session_cap(1);
    let mut engine = NativeEngine::synthetic(&cfg, 5, NativeSparsity::act(pattern)).unwrap();
    let mut pool = engine.new_kv_pool();
    let mut kv = pool.new_cache();
    let prompts: [Vec<u32>; 2] = [vec![3, 7, 11], vec![40, 1, 9, 9]];
    let max_new = 8;
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| engine.generate_greedy_sliding(&mut kv, &mut pool, p, max_new, &stop).unwrap())
        .collect();
    // Drive both sessions a step at a time through the backend, exactly
    // like the replica worker would.
    let mut rows: Vec<Vec<u32>> = prompts.to_vec();
    let mut got: Vec<Vec<u32>> = vec![Vec::new(); 2];
    let mut done = [false; 2];
    for _ in 0..max_new {
        let live: Vec<(u64, &[u32])> = (0..2)
            .filter(|i| !done[*i])
            .map(|i| (i as u64 + 1, rows[i].as_slice()))
            .collect();
        if live.is_empty() {
            break;
        }
        let ids: Vec<usize> = (0..2).filter(|i| !done[*i]).collect();
        let outs = backend.decode_step_sessions(&live).unwrap();
        for (i, out) in ids.into_iter().zip(outs) {
            match out.token() {
                Some(tok) => {
                    got[i].push(tok);
                    rows[i].push(tok);
                    if stop.contains(&tok) || got[i].len() >= max_new {
                        done[i] = true;
                    }
                }
                None => done[i] = true,
            }
        }
    }
    assert_eq!(got[0], want[0]);
    assert_eq!(got[1], want[1]);
    assert!(backend.engine().stats().steps > 0);
}

#[test]
fn coordinator_native_path_roundtrips_through_artifacts_format() {
    // Fabricate an artifacts directory from a synthetic model (the exact
    // files `aot.py` writes: io_manifest.json + ckpt.{bin,json} +
    // methodparams.{bin,json}, including per-site S-PTS eta vectors) and
    // pin Coordinator::generate_refs on the native path against the bare
    // engine. No PJRT is touched.
    let cfg = test_cfg(24);
    let model = nmsparse::engine::NativeModel::synthetic(&cfg, 21);
    let dir = std::env::temp_dir().join(format!("nmsparse-native-art-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    model.to_store().save(&dir.join("ckpt")).unwrap();
    let mut mp = TensorStore::new();
    mp.insert("placeholder", Tensor::scalar(0.0));
    // Per-site S-PTS eta vectors (what calibrate.py's spts_etas emits):
    // deterministic small shifts, one per (layer, site), site-width wide.
    for l in 0..cfg.n_layers {
        for site in nmsparse::engine::SITES {
            let din = cfg.site_in_dim(site);
            let eta: Vec<f32> = (0..din).map(|i| ((i % 5) as f32 - 2.0) * 0.25).collect();
            mp.insert(&format!("spts_eta.l{l}.{site}"), Tensor::from_vec(&[din], eta));
        }
    }
    mp.save(&dir.join("methodparams")).unwrap();
    let manifest = format!(
        r#"{{
  "config": {{"vocab": {}, "d_model": {}, "n_layers": {}, "n_heads": {},
             "ffn": {}, "eval_batch": 2, "eval_seq": {},
             "num_params": {}, "sites": ["q","k","v","o","gate","up","down"]}},
  "train": {{"final_loss": 0.0, "valid_ppl": 1.0, "steps": 0}},
  "variants": {{}}
}}"#,
        cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.ffn, cfg.max_seq,
        cfg.num_params()
    );
    std::fs::write(dir.join("io_manifest.json"), manifest).unwrap();

    let pattern = Pattern::NM { n: 8, m: 16 };
    let mcfg = MethodConfig::by_name("ACT", pattern).unwrap();
    let coord = Coordinator::open_native(&dir).unwrap();
    assert!(coord.uses_native());
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![10; 6]];
    let stop = vec![2u32];
    let got = coord.generate(&mcfg, &prompts, 6, &stop).unwrap();

    let mut engine = NativeEngine::new(model.clone(), NativeSparsity::act(pattern)).unwrap();
    let mut pool = engine.new_kv_pool();
    let mut kv = pool.new_cache();
    for (p, g) in prompts.iter().zip(&got) {
        let want = engine.generate_greedy(&mut kv, &mut pool, p, 6, &stop).unwrap();
        assert_eq!(g, &want, "prompt {p:?}");
    }
    assert!(coord.stats.tokens_generated() > 0);
    assert!(coord.stats.forwards() > 0);

    // The serving backend loads the same directory as real artifacts.
    let backend = NativeBackend::open(&dir, pattern, "ACT", stop.clone(), 4, 0).unwrap();
    assert_eq!(backend.origin, "artifacts");
    assert_eq!(backend.engine().config(), &cfg);

    // Calibrated S-PTS now runs natively: per-site eta vectors load from
    // the methodparams store and shift selection on every site. Build it
    // on a 2-wide worker pool (EnginePool plumbs the width to engines
    // built after the call) — the token comparisons below then also pin
    // that threading changes nothing on the artifacts path.
    coord.pool.set_native_threads(2);
    let spts = MethodConfig::by_name("S-PTS", pattern).unwrap();
    let native_spts = coord.pool.native_engine(&spts).unwrap();
    {
        let mut e = native_spts.borrow_mut();
        assert_eq!(e.threads(), 2, "EnginePool did not apply set_native_threads");
        assert!(e.sparsity().is_per_site());
        assert!(!e.uses_packed(), "eta-shifted pipelines are not selection-only");
        // And it decodes: tokens match a hand-built per-site engine.
        let mp = TensorStore::load(&dir.join("methodparams")).unwrap();
        let sparsity = NativeSparsity::from_method_with_params(&spts, &mp, &cfg).unwrap();
        let mut twin = NativeEngine::new(model.clone(), sparsity).unwrap();
        let mut tp = twin.new_kv_pool();
        let mut tkv = tp.new_cache();
        let want = twin.generate_greedy(&mut tkv, &mut tp, &[1, 2, 3], 5, &[]).unwrap();
        let mut ep = e.new_kv_pool();
        let mut ekv = ep.new_cache();
        let got = e.generate_greedy(&mut ekv, &mut ep, &[1, 2, 3], 5, &[]).unwrap();
        assert_eq!(got, want);
        // S-PTS actually changes the generation vs plain ACT somewhere
        // (same seeds, shifted selection) — not a silent ACT downgrade.
        let spts_differs = {
            let mut any = false;
            for p in 0..8u32 {
                let a = engine
                    .generate_greedy(&mut kv, &mut pool, &[p + 1, 2, 3], 6, &[])
                    .unwrap();
                let mut tkv2 = tp.new_cache();
                let b = twin.generate_greedy(&mut tkv2, &mut tp, &[p + 1, 2, 3], 6, &[]).unwrap();
                tkv2.reset(&mut tp);
                if a != b {
                    any = true;
                    break;
                }
            }
            any
        };
        assert!(spts_differs, "per-site eta had no effect on any probe prompt");
    }

    // Methods whose vectors are missing from the store still fail
    // loudly, never silently: L-PTS wants `lpts_eta.8_16.*` entries.
    let lpts = MethodConfig::by_name("L-PTS", pattern).unwrap();
    assert!(coord.pool.native_engine(&lpts).is_err());
    // And without any methodparams, S-PTS is rejected up front.
    assert!(NativeSparsity::from_method(&spts).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn context_exhausted_sessions_slide_instead_of_ending() {
    // The serving rule: a session at the context edge drops its oldest
    // page block and keeps generating. The backend must match the
    // sequential sliding reference token-for-token, and never end the
    // session early.
    let cfg = test_cfg(16);
    let pattern = Pattern::NM { n: 2, m: 4 };
    let page_tokens = 4usize;
    let mut backend = NativeBackend::synthetic(&cfg, 9, NativeSparsity::act(pattern), vec![], 4)
        .unwrap()
        .with_page_tokens(page_tokens);
    let mut engine = NativeEngine::synthetic(&cfg, 9, NativeSparsity::act(pattern)).unwrap();
    let mut pool = engine.new_kv_pool_with(page_tokens);
    let mut kv = pool.new_cache();
    let max_new = 10;
    // Prompts below, at, and past the context edge all keep generating
    // to the budget.
    for (id, len) in [(1u64, 12usize), (2, 16), (3, 19)] {
        let prompt: Vec<u32> = (0..len as u32).map(|i| i % 40).collect();
        let want =
            engine.generate_greedy_sliding(&mut kv, &mut pool, &prompt, max_new, &[]).unwrap();
        assert_eq!(want.len(), max_new, "sliding keeps the session alive (len={len})");
        let mut row = prompt.clone();
        let mut got = Vec::new();
        for _ in 0..max_new {
            let outs = backend.decode_step_sessions(&[(id, row.as_slice())]).unwrap();
            let tok = outs[0].token().expect("sliding sessions never end on context");
            got.push(tok);
            row.push(tok);
        }
        assert_eq!(got, want, "len={len}");
        backend.end_session(id);
    }
    // Peak KV stays bounded by the window, not the ever-growing row.
    let window_pages = cfg.max_seq.div_ceil(page_tokens);
    assert!(
        backend.pages().peak_pages() <= window_pages + 1,
        "peak {} pages vs window {window_pages}",
        backend.pages().peak_pages()
    );
}
