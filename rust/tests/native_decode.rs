//! Decode-equivalence property suite for the native engine (no
//! artifacts, no PJRT): KV-cached incremental decode must be
//! token-identical to the full-context reference loop across patterns,
//! prompt shapes and stop-token placements, and must survive every cache
//! lifecycle edge — reset, truncation, LRU eviction, re-prefill — plus
//! the artifacts-format round trip through `Coordinator`'s native path.

use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::coordinator::server::{NativeBackend, ReplicaBackend};
use nmsparse::coordinator::Coordinator;
use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::sparsity::Pattern;
use nmsparse::util::miniprop::{forall_simple, Config};
use nmsparse::util::prng::Rng;

fn test_cfg(max_seq: usize) -> EngineConfig {
    EngineConfig {
        vocab: 48,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        ffn: 64,
        max_seq,
    }
}

fn patterns() -> Vec<Pattern> {
    vec![
        Pattern::Dense,
        Pattern::NM { n: 2, m: 4 },
        Pattern::NM { n: 8, m: 16 },
        Pattern::NM { n: 16, m: 32 },
        Pattern::Unstructured { keep_pct: 50 },
    ]
}

#[test]
fn prop_kv_cached_decode_token_identical_to_full_context() {
    // The acceptance property: across patterns (2:4, 8:16, 16:32, dense,
    // u50), model seeds, prompt lengths, budgets and stop-token
    // placements, the KV-cached loop and the full-context loop emit the
    // same tokens.
    let cfg = Config { cases: 24, ..Config::default() };
    let pats = patterns();
    forall_simple(
        &cfg,
        |rng: &mut Rng| {
            let pattern = *rng.choose(&pats);
            let seed = rng.next_u64();
            let plen = rng.range(1, 12);
            let prompt: Vec<u32> = (0..plen).map(|_| rng.range(0, 48) as u32).collect();
            let max_new = rng.range(1, 14);
            // Half the cases pick stop tokens from the vocab (sometimes
            // hitting mid-generation), half run stop-free.
            let stops: Vec<u32> = if rng.chance(0.5) {
                (0..rng.range(1, 4)).map(|_| rng.range(0, 48) as u32).collect()
            } else {
                Vec::new()
            };
            (pattern, seed, prompt, max_new, stops)
        },
        |(pattern, seed, prompt, max_new, stops)| {
            let mut e =
                NativeEngine::synthetic(&test_cfg(32), *seed, NativeSparsity::act(*pattern))
                    .unwrap();
            let mut kv = e.new_cache();
            let cached = e.generate_greedy(&mut kv, prompt, *max_new, stops).unwrap();
            let full = e.generate_greedy_full(&mut kv, prompt, *max_new, stops).unwrap();
            cached == full && !cached.is_empty() && cached.len() <= *max_new
        },
    );
}

#[test]
fn prop_stop_token_placement_truncates_identically() {
    // Take a free-running generation, pick each of its tokens as the stop
    // token in turn, and pin that both loops cut at exactly that point.
    let cfg = Config { cases: 10, ..Config::default() };
    forall_simple(
        &cfg,
        |rng: &mut Rng| (rng.next_u64(), rng.range(1, 6)),
        |(seed, plen)| {
            let pattern = Pattern::NM { n: 8, m: 16 };
            let mut e =
                NativeEngine::synthetic(&test_cfg(32), *seed, NativeSparsity::act(pattern))
                    .unwrap();
            let mut kv = e.new_cache();
            let prompt: Vec<u32> = (0..*plen).map(|i| (i * 7 % 48) as u32).collect();
            let free = e.generate_greedy(&mut kv, &prompt, 8, &[]).unwrap();
            for (i, stop) in free.iter().enumerate() {
                let cached = e.generate_greedy(&mut kv, &prompt, 8, &[*stop]).unwrap();
                let full = e.generate_greedy_full(&mut kv, &prompt, 8, &[*stop]).unwrap();
                if cached != full {
                    return false;
                }
                // Cut at the first occurrence of the stop token.
                let first = free.iter().position(|t| t == stop).unwrap();
                if first <= i && cached != free[..=first].to_vec() {
                    return false;
                }
            }
            true
        },
    );
}

#[test]
fn cache_reuse_and_reset_are_stateless() {
    // One cache object reused (reset) across many prompts must match
    // fresh caches exactly.
    let pattern = Pattern::NM { n: 2, m: 4 };
    let mut e = NativeEngine::synthetic(&test_cfg(32), 11, NativeSparsity::act(pattern)).unwrap();
    let mut shared = e.new_cache();
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![40, 41], vec![7; 10], vec![0]];
    let mut first = Vec::new();
    for p in &prompts {
        first.push(e.generate_greedy(&mut shared, p, 6, &[]).unwrap());
    }
    for (p, want) in prompts.iter().zip(&first) {
        let mut fresh = e.new_cache();
        assert_eq!(&e.generate_greedy(&mut fresh, p, 6, &[]).unwrap(), want);
    }
}

#[test]
fn truncate_rolls_back_to_identical_logits() {
    // Truncating the cache to a prefix and re-stepping must be
    // indistinguishable from prefilling that prefix fresh.
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut e = NativeEngine::synthetic(&test_cfg(32), 13, NativeSparsity::act(pattern)).unwrap();
    let row: Vec<u32> = (0..20).map(|i| (i * 5 % 48) as u32).collect();
    let mut kv = e.new_cache();
    e.prefill(&mut kv, &row).unwrap();
    for cut in [1usize, 7, 19] {
        kv.truncate(cut);
        e.step(&mut kv, row[cut]).unwrap();
        let after_truncate: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
        let mut fresh = e.new_cache();
        e.prefill(&mut fresh, &row[..cut + 1]).unwrap();
        let from_fresh: Vec<u32> = e.logits().iter().map(|v| v.to_bits()).collect();
        assert_eq!(after_truncate, from_fresh, "cut={cut}");
        // Restore for the next cut.
        kv.reset();
        e.prefill(&mut kv, &row).unwrap();
    }
}

#[test]
fn session_eviction_under_cap_one_is_token_identical() {
    // Two interleaved sessions on a cap-1 KV pool force an eviction and
    // a full re-prefill on every step — tokens must not change.
    let cfg = test_cfg(32);
    let pattern = Pattern::NM { n: 8, m: 16 };
    let stop: Vec<u32> = vec![2];
    let mut backend =
        NativeBackend::synthetic(&cfg, 5, NativeSparsity::act(pattern), stop.clone(), 4)
            .unwrap()
            .with_session_cap(1);
    let mut engine = NativeEngine::synthetic(&cfg, 5, NativeSparsity::act(pattern)).unwrap();
    let mut kv = engine.new_cache();
    let prompts: [Vec<u32>; 2] = [vec![3, 7, 11], vec![40, 1, 9, 9]];
    let max_new = 8;
    let want: Vec<Vec<u32>> = prompts
        .iter()
        .map(|p| engine.generate_greedy(&mut kv, p, max_new, &stop).unwrap())
        .collect();
    // Drive both sessions a step at a time through the backend, exactly
    // like the replica worker would.
    let mut rows: Vec<Vec<u32>> = prompts.to_vec();
    let mut got: Vec<Vec<u32>> = vec![Vec::new(); 2];
    let mut done = [false; 2];
    for _ in 0..max_new {
        let live: Vec<(u64, &[u32])> = (0..2)
            .filter(|i| !done[*i])
            .map(|i| (i as u64 + 1, rows[i].as_slice()))
            .collect();
        if live.is_empty() {
            break;
        }
        let ids: Vec<usize> = (0..2).filter(|i| !done[*i]).collect();
        let outs = backend.decode_step_sessions(&live).unwrap();
        for (i, out) in ids.into_iter().zip(outs) {
            match out {
                Some(tok) => {
                    got[i].push(tok);
                    rows[i].push(tok);
                    if stop.contains(&tok) || got[i].len() >= max_new {
                        done[i] = true;
                    }
                }
                None => done[i] = true,
            }
        }
    }
    assert_eq!(got[0], want[0]);
    assert_eq!(got[1], want[1]);
}

#[test]
fn coordinator_native_path_roundtrips_through_artifacts_format() {
    // Fabricate an artifacts directory from a synthetic model (the exact
    // files `aot.py` writes: io_manifest.json + ckpt.{bin,json} +
    // methodparams.{bin,json}) and pin Coordinator::generate_refs on the
    // native path against the bare engine. No PJRT is touched.
    let cfg = test_cfg(24);
    let model = nmsparse::engine::NativeModel::synthetic(&cfg, 21);
    let dir = std::env::temp_dir().join(format!("nmsparse-native-art-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    model.to_store().save(&dir.join("ckpt")).unwrap();
    let mut mp = nmsparse::util::tensor::TensorStore::new();
    mp.insert("placeholder", nmsparse::util::tensor::Tensor::scalar(0.0));
    mp.save(&dir.join("methodparams")).unwrap();
    let manifest = format!(
        r#"{{
  "config": {{"vocab": {}, "d_model": {}, "n_layers": {}, "n_heads": {},
             "ffn": {}, "eval_batch": 2, "eval_seq": {},
             "num_params": {}, "sites": ["q","k","v","o","gate","up","down"]}},
  "train": {{"final_loss": 0.0, "valid_ppl": 1.0, "steps": 0}},
  "variants": {{}}
}}"#,
        cfg.vocab, cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.ffn, cfg.max_seq,
        cfg.num_params()
    );
    std::fs::write(dir.join("io_manifest.json"), manifest).unwrap();

    let pattern = Pattern::NM { n: 8, m: 16 };
    let mcfg = MethodConfig::by_name("ACT", pattern).unwrap();
    let coord = Coordinator::open_native(&dir).unwrap();
    assert!(coord.uses_native());
    let prompts: Vec<Vec<u32>> = vec![vec![1, 2, 3], vec![10; 6]];
    let stop = vec![2u32];
    let got = coord.generate(&mcfg, &prompts, 6, &stop).unwrap();

    let mut engine = NativeEngine::new(model, NativeSparsity::act(pattern)).unwrap();
    let mut kv = engine.new_cache();
    for (p, g) in prompts.iter().zip(&got) {
        let want = engine.generate_greedy(&mut kv, p, 6, &stop).unwrap();
        assert_eq!(g, &want, "prompt {p:?}");
    }
    assert!(coord.stats.tokens_generated() > 0);
    assert!(coord.stats.forwards() > 0);

    // The serving backend loads the same directory as real artifacts.
    let backend = NativeBackend::open(&dir, pattern, "ACT", stop, 4, 0).unwrap();
    assert_eq!(backend.origin, "artifacts");
    assert_eq!(backend.engine().config(), &cfg);

    // Methods the native engine cannot realize fail loudly, not silently.
    let spts = MethodConfig::by_name("S-PTS", pattern).unwrap();
    assert!(coord.pool.native_engine(&spts).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn context_exhaustion_ends_sessions_cleanly() {
    let cfg = test_cfg(16);
    let pattern = Pattern::NM { n: 2, m: 4 };
    let mut backend =
        NativeBackend::synthetic(&cfg, 9, NativeSparsity::act(pattern), vec![], 4).unwrap();
    let mut engine = NativeEngine::synthetic(&cfg, 9, NativeSparsity::act(pattern)).unwrap();
    let mut kv = engine.new_cache();
    // A fresh prompt at/past the context edge gets exactly the one
    // budget-rule token `generate_greedy` emits (left-cropped), and the
    // *next* step ends the session with None.
    for (id, len) in [(1u64, 17usize), (2, 16)] {
        let prompt: Vec<u32> = (0..len as u32).map(|i| i % 40).collect();
        let want = engine.generate_greedy(&mut kv, &prompt, 8, &[]).unwrap();
        assert_eq!(want.len(), 1, "budget rule emits exactly one token");
        let outs = backend.decode_step_sessions(&[(id, prompt.as_slice())]).unwrap();
        assert_eq!(outs, vec![Some(want[0])], "len={len}");
        let mut grown = prompt.clone();
        grown.push(want[0]);
        let outs = backend.decode_step_sessions(&[(id, grown.as_slice())]).unwrap();
        assert_eq!(outs, vec![None], "len={len}");
    }
}
