//! Forward-path benchmarks over the PJRT executables: dense vs every
//! sparsity pattern, scoring throughput, decode-step latency, engine bind
//! cost. These are the perf numbers behind EXPERIMENTS.md §Perf — the cost
//! of *emulating* dynamic sparsity in HLO on CPU (the paper's Appendix-A
//! hardware model covers what native support would recover).
//!
//! Requires `make artifacts`; skips gracefully if they are missing.

use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::coordinator::Coordinator;
use nmsparse::sparsity::Pattern;
use nmsparse::util::bench::BenchSuite;
use std::path::Path;

fn main() {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("io_manifest.json").exists() {
        println!("forward: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let coord = Coordinator::open(artifacts).expect("open artifacts");
    let dims = coord.pool.manifest.dims.clone();
    let tokens_per_batch = (dims.batch * dims.seq) as f64;
    let mut suite = BenchSuite::new("forward");
    suite.target_time_s = 3.0;
    suite.samples = 8;

    // A deterministic token batch (valid ids, full lengths).
    let tokens: Vec<i32> = (0..dims.batch * dims.seq)
        .map(|i| (i % 97) as i32)
        .collect();
    let lens = vec![dims.seq as i32; dims.batch];

    // ---- dense vs patterns: batched forward tokens/s ----
    for key in ["dense", "2:4", "4:8", "8:16", "16:32", "u50"] {
        let cfg = if key == "dense" {
            MethodConfig::dense()
        } else {
            MethodConfig::act(Pattern::parse(key).unwrap())
        };
        let engine = coord.pool.engine(&cfg).expect("engine");
        suite.bench_with_items(
            &format!("forward/{key} batch (tokens)"),
            Some(tokens_per_batch),
            || {
                std::hint::black_box(engine.run(&coord.pool.rt, &tokens, &lens).unwrap());
            },
        );
    }

    // ---- method-parameter cost: transforms on top of 8:16 ----
    for name in ["S-PTS", "D-PTS", "VAR", "CLACT", "R-Sparse(64)"] {
        let cfg = MethodConfig::by_name(name, Pattern::NM { n: 8, m: 16 }).unwrap();
        let engine = coord.pool.engine(&cfg).expect("engine");
        suite.bench_with_items(
            &format!("forward/8:16+{name} (tokens)"),
            Some(tokens_per_batch),
            || {
                std::hint::black_box(engine.run(&coord.pool.rt, &tokens, &lens).unwrap());
            },
        );
    }

    // ---- scoring path end-to-end (pack + run + reduce) ----
    {
        let cfg = MethodConfig::act(Pattern::NM { n: 8, m: 16 });
        let rows: Vec<(Vec<u32>, (usize, usize))> = (0..dims.batch)
            .map(|i| {
                let row: Vec<u32> = (0..24).map(|t| ((i * 7 + t) % 97) as u32).collect();
                (row, (20, 24))
            })
            .collect();
        suite.bench_with_items(
            "score_rows/8:16 one batch of rows (rows)",
            Some(dims.batch as f64),
            || {
                std::hint::black_box(coord.score_rows(&cfg, &rows).unwrap());
            },
        );
    }

    // ---- decode step latency (single token across a full batch) ----
    {
        let cfg = MethodConfig::act(Pattern::NM { n: 8, m: 16 });
        let prompts: Vec<Vec<u32>> = (0..dims.batch)
            .map(|i| (0..10).map(|t| ((i + t) % 97) as u32).collect())
            .collect();
        suite.bench_with_items(
            "generate/8:16 one step x batch (tokens)",
            Some(dims.batch as f64),
            || {
                std::hint::black_box(coord.generate(&cfg, &prompts, 1, &[]).unwrap());
            },
        );
    }

    // ---- bind cost (weights upload + resolver) ----
    {
        let variant = coord.pool.variant("8_16").unwrap();
        let cfg = MethodConfig::act(Pattern::NM { n: 8, m: 16 });
        suite.bench(
            "bind/8_16 resolve+upload all inputs",
            || {
                let resolver = cfg.resolver(&coord.pool.weights, &coord.pool.methodparams);
                std::hint::black_box(variant.bind(&coord.pool.rt, &resolver).unwrap());
            },
        );
    }

    suite.finish();
}
