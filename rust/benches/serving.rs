//! Serving-layer benchmarks: batcher/scheduler/packing logic (pure rust)
//! — the coordinator must stay negligible next to the PJRT executable.

use nmsparse::coordinator::batcher::{pack_rows, BatchPolicy, Batcher};
use nmsparse::coordinator::scheduler::{SchedPolicy, Scheduler, Work};
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::prng::Rng;
use std::time::Duration;

fn main() {
    let mut suite = BenchSuite::new("serving");
    let mut rng = Rng::new(7);

    // ---- dynamic batcher ----
    {
        let policy = BatchPolicy {
            capacity: 16,
            max_wait: Duration::from_millis(5),
        };
        suite.bench_with_items("batcher/push+drain 1024 items (items)", Some(1024.0), || {
            let mut b = Batcher::new(policy);
            for i in 0..1024usize {
                b.push(i);
            }
            let mut total = 0;
            while !b.is_empty() {
                total += b.drain_batch().len();
            }
            std::hint::black_box(total);
        });
        // The serve-loop pattern: one reused buffer across flushes.
        let mut buf: Vec<usize> = Vec::new();
        suite.bench_with_items(
            "batcher/push+drain_into 1024 items (items)",
            Some(1024.0),
            move || {
                let mut b = Batcher::new(policy);
                for i in 0..1024usize {
                    b.push(i);
                }
                let mut total = 0;
                while !b.is_empty() {
                    b.drain_batch_into(&mut buf);
                    total += buf.len();
                }
                std::hint::black_box(total);
            },
        );
    }

    // ---- row packing ----
    {
        let rows: Vec<Vec<u32>> = (0..256)
            .map(|_| {
                let len = rng.range(4, 60);
                (0..len).map(|_| rng.below(150) as u32).collect()
            })
            .collect();
        let tokens: f64 = rows.iter().map(|r| r.len() as f64).sum();
        suite.bench_with_items("pack_rows/256 rows into 16x64 (tokens)", Some(tokens), || {
            std::hint::black_box(pack_rows(&rows, 16, 64));
        });
    }

    // ---- scheduler under mixed load ----
    {
        suite.bench_with_items(
            "scheduler/mixed 64 scores + 16 gens to completion (reqs)",
            Some(80.0),
            || {
                let mut s = Scheduler::new(16, SchedPolicy::default());
                for i in 0..64u32 {
                    s.submit_score(vec![i], (0, 1));
                }
                for i in 0..16u32 {
                    s.submit_generate(vec![i], 8);
                }
                loop {
                    match s.next_work() {
                        Work::Idle => break,
                        Work::Score(ids) => {
                            for id in ids {
                                s.complete_score(id);
                            }
                        }
                        Work::Decode(ids) => {
                            for id in ids {
                                s.session_mut(id).unwrap().push_token(1, &[]);
                            }
                            s.reap_done();
                        }
                    }
                }
                std::hint::black_box(&s);
            },
        );
    }

    suite.finish();
}
