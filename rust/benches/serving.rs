//! Serving-layer benchmarks: batcher/scheduler/packing logic (pure rust)
//! — the coordinator must stay negligible next to the PJRT executable —
//! plus an end-to-end multi-replica `ServerCore` run through the same
//! loadgen harness `nmsparse loadgen` uses, dumped to
//! `BENCH_serving.json` for `nmsparse table serving` and the CI schema
//! gate.

use nmsparse::coordinator::batcher::{pack_rows, BatchPolicy, Batcher};
use nmsparse::coordinator::scheduler::{SchedPolicy, Scheduler, Work};
use nmsparse::launcher::loadgen::{self, BackendChoice, LoadgenConfig, Mode};
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::prng::Rng;
use nmsparse::wire::{CodecKind, WireRequest};
use std::time::Duration;

fn main() {
    let mut suite = BenchSuite::new("serving");
    let mut rng = Rng::new(7);

    // ---- dynamic batcher ----
    {
        let policy = BatchPolicy {
            capacity: 16,
            max_wait: Duration::from_millis(5),
        };
        suite.bench_with_items("batcher/push+drain 1024 items (items)", Some(1024.0), || {
            let mut b = Batcher::new(policy);
            for i in 0..1024usize {
                b.push(i);
            }
            let mut total = 0;
            while !b.is_empty() {
                total += b.drain_batch().len();
            }
            std::hint::black_box(total);
        });
        // The serve-loop pattern: one reused buffer across flushes.
        let mut buf: Vec<usize> = Vec::new();
        suite.bench_with_items(
            "batcher/push+drain_into 1024 items (items)",
            Some(1024.0),
            move || {
                let mut b = Batcher::new(policy);
                for i in 0..1024usize {
                    b.push(i);
                }
                let mut total = 0;
                while !b.is_empty() {
                    b.drain_batch_into(&mut buf);
                    total += buf.len();
                }
                std::hint::black_box(total);
            },
        );
    }

    // ---- row packing ----
    {
        let rows: Vec<Vec<u32>> = (0..256)
            .map(|_| {
                let len = rng.range(4, 60);
                (0..len).map(|_| rng.below(150) as u32).collect()
            })
            .collect();
        let tokens: f64 = rows.iter().map(|r| r.len() as f64).sum();
        suite.bench_with_items("pack_rows/256 rows into 16x64 (tokens)", Some(tokens), || {
            std::hint::black_box(pack_rows(&rows, 16, 64));
        });
    }

    // ---- scheduler under mixed load ----
    {
        suite.bench_with_items(
            "scheduler/mixed 64 scores + 16 gens to completion (reqs)",
            Some(80.0),
            || {
                let mut s = Scheduler::new(16, SchedPolicy::default());
                for i in 0..64u32 {
                    s.submit_score(vec![i], (0, 1));
                }
                for i in 0..16u32 {
                    s.submit_generate(vec![i], 8);
                }
                loop {
                    match s.next_work() {
                        Work::Idle => break,
                        Work::Score(ids) => {
                            for id in ids {
                                s.complete_score(id);
                            }
                        }
                        Work::Decode(ids) => {
                            for id in ids {
                                s.session_mut(id).unwrap().push_token(1, &[]);
                            }
                            s.reap_done();
                        }
                    }
                }
                std::hint::black_box(&s);
            },
        );
    }

    // ---- wire codecs ----
    //
    // Encode -> decode of the token-level request twins through both
    // codecs: the framing layer must stay negligible next to a forward.
    {
        let reqs: Vec<WireRequest> = (0..256)
            .map(|i| {
                let len = rng.range(4, 48);
                let tokens: Vec<u32> = (0..len).map(|_| rng.below(150) as u32).collect();
                if i % 2 == 0 {
                    let span = (1, (len - 1) as u32);
                    WireRequest::ScoreTokens { tokens, span, tenant: (i % 4) as u32 }
                } else {
                    let (tenant, stream) = ((i % 4) as u32, i % 3 == 0);
                    WireRequest::GenerateTokens { tokens, max_new: 8, tenant, stream }
                }
            })
            .collect();
        for kind in [CodecKind::Json, CodecKind::Binary] {
            let c = kind.codec();
            let name = format!("wire/{} codec roundtrip 256 requests (reqs)", kind.as_str());
            suite.bench_with_items(&name, Some(256.0), || {
                let mut buf = Vec::new();
                for r in &reqs {
                    buf.clear();
                    c.encode_request(r, &mut buf);
                    let decoded = c.decode_request(&buf).expect("frame").expect("complete").0;
                    std::hint::black_box(decoded);
                }
            });
        }
    }

    // ---- end-to-end ServerCore under load (BENCH_serving.json) ----
    //
    // Reuses the loadgen harness: 2 synthetic replicas with a simulated
    // per-forward cost, closed-loop clients, server-side latency
    // histogram. Skipped under --filter unless it matches.
    {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 64,
            max_requests: 512,
            concurrency: 16,
            rate_rps: 0.0,
            mode: Mode::Mixed,
            max_new: 8,
            max_wait: Duration::from_millis(2),
            seed: 7,
            backend: BackendChoice::Synthetic {
                batch: 16,
                forward_cost: Duration::from_micros(150),
            },
            // Two tenant classes on a 3:1 traffic mix with equal dispatch
            // weights, so the emitted BENCH_serving.json carries a real
            // per-tenant breakdown for the checker's fairness gate.
            tenants: loadgen::parse_tenant_plan("2:3,1").expect("tenant plan"),
            ..Default::default()
        };
        let name = "server_core/closed-loop 512 mixed x2 replicas (reqs)";
        let mut last = None;
        suite.bench_with_items(name, Some(cfg.max_requests as f64), || {
            last = Some(loadgen::run(&cfg).expect("loadgen run"));
        });
        if let Some(report) = last {
            println!("server_core: {}", report.summary());
            // loadgen::run records at metrics level, so the report must
            // carry a non-empty per-phase breakdown even on the
            // synthetic backend (queue_wait/tick_build/reply at least).
            assert!(
                report.phases.phases.iter().any(|p| p.count > 0),
                "loadgen run produced an empty phases breakdown"
            );
            assert_eq!(report.stats.tenants.len(), 2, "per-tenant breakdown missing");
            assert!(
                report.stats.tenants.iter().all(|t| t.submitted > 0),
                "a tenant class saw no traffic"
            );
            println!("server_core: {}", report.phases.summary());
            match loadgen::write_bench_json(&report, std::path::Path::new("BENCH_serving.json")) {
                Ok(()) => println!("wrote BENCH_serving.json"),
                Err(e) => eprintln!("could not write BENCH_serving.json: {e}"),
            }
        }
    }

    suite.finish();
}
