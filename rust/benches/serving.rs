//! Serving-layer benchmarks: batcher/scheduler/packing logic (pure rust)
//! — the coordinator must stay negligible next to the PJRT executable —
//! plus an end-to-end multi-replica `ServerCore` run through the same
//! loadgen harness `nmsparse loadgen` uses, dumped to
//! `BENCH_serving.json` for `nmsparse table serving` and the CI schema
//! gate.

use nmsparse::coordinator::batcher::{pack_rows, BatchPolicy, Batcher};
use nmsparse::coordinator::scheduler::{SchedPolicy, Scheduler, Work};
use nmsparse::launcher::loadgen::{self, BackendChoice, LoadgenConfig, Mode};
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::prng::Rng;
use std::time::Duration;

fn main() {
    let mut suite = BenchSuite::new("serving");
    let mut rng = Rng::new(7);

    // ---- dynamic batcher ----
    {
        let policy = BatchPolicy {
            capacity: 16,
            max_wait: Duration::from_millis(5),
        };
        suite.bench_with_items("batcher/push+drain 1024 items (items)", Some(1024.0), || {
            let mut b = Batcher::new(policy);
            for i in 0..1024usize {
                b.push(i);
            }
            let mut total = 0;
            while !b.is_empty() {
                total += b.drain_batch().len();
            }
            std::hint::black_box(total);
        });
        // The serve-loop pattern: one reused buffer across flushes.
        let mut buf: Vec<usize> = Vec::new();
        suite.bench_with_items(
            "batcher/push+drain_into 1024 items (items)",
            Some(1024.0),
            move || {
                let mut b = Batcher::new(policy);
                for i in 0..1024usize {
                    b.push(i);
                }
                let mut total = 0;
                while !b.is_empty() {
                    b.drain_batch_into(&mut buf);
                    total += buf.len();
                }
                std::hint::black_box(total);
            },
        );
    }

    // ---- row packing ----
    {
        let rows: Vec<Vec<u32>> = (0..256)
            .map(|_| {
                let len = rng.range(4, 60);
                (0..len).map(|_| rng.below(150) as u32).collect()
            })
            .collect();
        let tokens: f64 = rows.iter().map(|r| r.len() as f64).sum();
        suite.bench_with_items("pack_rows/256 rows into 16x64 (tokens)", Some(tokens), || {
            std::hint::black_box(pack_rows(&rows, 16, 64));
        });
    }

    // ---- scheduler under mixed load ----
    {
        suite.bench_with_items(
            "scheduler/mixed 64 scores + 16 gens to completion (reqs)",
            Some(80.0),
            || {
                let mut s = Scheduler::new(16, SchedPolicy::default());
                for i in 0..64u32 {
                    s.submit_score(vec![i], (0, 1));
                }
                for i in 0..16u32 {
                    s.submit_generate(vec![i], 8);
                }
                loop {
                    match s.next_work() {
                        Work::Idle => break,
                        Work::Score(ids) => {
                            for id in ids {
                                s.complete_score(id);
                            }
                        }
                        Work::Decode(ids) => {
                            for id in ids {
                                s.session_mut(id).unwrap().push_token(1, &[]);
                            }
                            s.reap_done();
                        }
                    }
                }
                std::hint::black_box(&s);
            },
        );
    }

    // ---- end-to-end ServerCore under load (BENCH_serving.json) ----
    //
    // Reuses the loadgen harness: 2 synthetic replicas with a simulated
    // per-forward cost, closed-loop clients, server-side latency
    // histogram. Skipped under --filter unless it matches.
    {
        let cfg = LoadgenConfig {
            replicas: 2,
            queue_cap: 64,
            max_requests: 512,
            concurrency: 16,
            rate_rps: 0.0,
            mode: Mode::Mixed,
            max_new: 8,
            max_wait: Duration::from_millis(2),
            seed: 7,
            backend: BackendChoice::Synthetic {
                batch: 16,
                forward_cost: Duration::from_micros(150),
            },
            ..Default::default()
        };
        let name = "server_core/closed-loop 512 mixed x2 replicas (reqs)";
        let mut last = None;
        suite.bench_with_items(name, Some(cfg.max_requests as f64), || {
            last = Some(loadgen::run(&cfg).expect("loadgen run"));
        });
        if let Some(report) = last {
            println!("server_core: {}", report.summary());
            // loadgen::run records at metrics level, so the report must
            // carry a non-empty per-phase breakdown even on the
            // synthetic backend (queue_wait/tick_build/reply at least).
            assert!(
                report.phases.phases.iter().any(|p| p.count > 0),
                "loadgen run produced an empty phases breakdown"
            );
            println!("server_core: {}", report.phases.summary());
            match loadgen::write_bench_json(&report, std::path::Path::new("BENCH_serving.json")) {
                Ok(()) => println!("wrote BENCH_serving.json"),
                Err(e) => eprintln!("could not write BENCH_serving.json: {e}"),
            }
        }
    }

    suite.finish();
}
