//! Table-regeneration benchmarks: wall-clock for each paper table/figure
//! harness at a small example budget. One bench per table satisfies
//! "a bench per paper table AND figure"; the accuracy *content* of each
//! table is produced by `nmsparse table <id>` (same code path).
//!
//! Requires `make artifacts`; skips gracefully if missing.

use nmsparse::tables::{generate, TableCtx};
use nmsparse::util::bench::BenchSuite;
use std::path::Path;

fn main() {
    if !Path::new("artifacts/io_manifest.json").exists() {
        println!("tables: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let mut suite = BenchSuite::new("tables");
    suite.target_time_s = 1.0;
    suite.samples = 2;

    // Small budget so the full sweep stays minutes, not hours. Engines and
    // eval results are cached inside the ctx after the first sample, so the
    // numbers reflect the warm regeneration cost.
    let mut ctx = TableCtx::open("artifacts", "artifacts/data", 16).expect("ctx");
    ctx.ifeval_limit = 8;
    ctx.max_new = 8;
    ctx.windows = 4;

    for id in [
        "table6", "fig2", "fig1", "table2", "table4", "table8", "table11",
        "table12", "table14", "table5", "table3",
    ] {
        suite.bench(&format!("table/{id} (warm, 16 ex)"), || {
            std::hint::black_box(generate(&mut ctx, id).expect(id));
        });
    }
    println!(
        "total forwards issued during bench: {}",
        ctx.coord.forwards.get()
    );
    suite.finish();
}
