//! Table-regeneration benchmarks: wall-clock for each paper table/figure
//! harness at a small example budget. One bench per table satisfies
//! "a bench per paper table AND figure"; the accuracy *content* of each
//! table is produced by `nmsparse table <id>` (same code path).
//!
//! Also measures the fused pipeline's per-forward software sparsification
//! cost as a fraction of end-to-end forward time per pattern, and writes
//! it to `BENCH_sparsify_overhead.json` — the measured software baseline
//! that `table6` and `examples/hw_breakeven.rs` cite for the EDP model's
//! alpha (instead of only the paper's analytic 0.3).
//!
//! Requires `make artifacts`; skips gracefully if missing.

use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::sparsity::{Pattern, Sparsifier};
use nmsparse::synthlang::corpus::Corpus;
use nmsparse::tables::{generate, TableCtx, OVERHEAD_BENCH_FILE};
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::json::Json;
use nmsparse::util::prng::Rng;
use nmsparse::util::tensor::Tensor;
use nmsparse::util::threadpool;
use std::path::Path;
use std::time::Instant;

fn main() {
    if !Path::new("artifacts/io_manifest.json").exists() {
        println!("tables: artifacts/ missing — run `make artifacts` first; skipping");
        return;
    }
    let mut suite = BenchSuite::new("tables");
    suite.target_time_s = 1.0;
    suite.samples = 2;

    // Small budget so the full sweep stays minutes, not hours. Engines and
    // eval results are cached inside the ctx after the first sample, so the
    // numbers reflect the warm regeneration cost.
    let mut ctx = TableCtx::open("artifacts", "artifacts/data", 16).expect("ctx");
    ctx.ifeval_limit = 8;
    ctx.max_new = 8;
    ctx.windows = 4;

    for id in [
        "table6", "fig2", "fig1", "table2", "table4", "table8", "table11",
        "table12", "table14", "table5", "table3",
    ] {
        suite.bench(&format!("table/{id} (warm, 16 ex)"), || {
            std::hint::black_box(generate(&mut ctx, id).expect(id));
        });
    }

    sparsify_overhead_report(&ctx);

    println!("total during bench: {}", ctx.coord.stats.summary());
    suite.finish();
}

/// Measure end-to-end forward time (dense engine, warm) and the fused
/// pipeline's software sparsification cost per forward, per pattern.
///
/// One forward consumes `batch × seq` token rows; every sparsified site
/// (`sites × layers`) would run the pipeline over a `[batch·seq, d_model]`
/// activation matrix on a software-only deployment, so
/// `overhead_frac = sites · t_sparsify(batch·seq × d_model) / t_forward`.
fn sparsify_overhead_report(ctx: &TableCtx) {
    let dims = ctx.coord.pool.manifest.dims.clone();
    let act_rows = dims.batch * dims.seq;
    let site_calls = dims.sites.len() * dims.n_layers;
    let threads = threadpool::default_threads();

    // Forward time: score a validation window on the (already warm) dense
    // engine and average over a few repeats.
    let dense = MethodConfig::dense();
    let stream = match Corpus::read_tokens(Path::new("artifacts/data/corpus_valid.tokens")) {
        Ok(s) => s,
        Err(e) => {
            println!("sparsify-overhead: no validation corpus ({e}); skipping");
            return;
        }
    };
    let forwards_before = ctx.coord.stats.forwards();
    let t0 = Instant::now();
    for _ in 0..3 {
        if let Err(e) = ctx.coord.perplexity(&dense, &stream, 2) {
            println!("sparsify-overhead: forward failed ({e}); skipping");
            return;
        }
    }
    let n_forwards = ctx.coord.stats.forwards() - forwards_before;
    if n_forwards == 0 {
        println!("sparsify-overhead: no forwards issued; skipping");
        return;
    }
    let forward_s = t0.elapsed().as_secs_f64() / n_forwards as f64;

    let mut rng = Rng::new(0xBEEF);
    let x = Tensor::from_vec(
        &[act_rows, dims.d_model],
        (0..act_rows * dims.d_model)
            .map(|_| rng.normal() as f32)
            .collect(),
    );

    println!(
        "\n-- software sparsify overhead vs forward ({}x{} acts, {} site calls, {:.2}ms/forward) --",
        act_rows,
        dims.d_model,
        site_calls,
        forward_s * 1e3
    );
    let mut patterns = Json::obj();
    for key in ["2:4", "8:16", "16:32", "u50"] {
        let pattern = Pattern::parse(key).unwrap();
        let sp = Sparsifier::new(pattern);
        let mut buf = x.clone();
        // Calibrate repeats so the measurement is not timer-noise bound.
        let reps = 5usize;
        let t0 = Instant::now();
        for _ in 0..reps {
            buf.data.copy_from_slice(&x.data);
            sp.sparsify_batch(&mut buf, threads);
        }
        let per_matrix_s = t0.elapsed().as_secs_f64() / reps as f64;
        let per_forward_s = per_matrix_s * site_calls as f64;
        let frac = per_forward_s / forward_s;
        println!(
            "{:<8} {:>10.3}ms/site-matrix {:>10.3}ms/forward  overhead {:>7.4} of forward",
            key,
            per_matrix_s * 1e3,
            per_forward_s * 1e3,
            frac
        );
        let mut p = Json::obj();
        p.insert("sparsify_s_per_site_matrix", per_matrix_s.into());
        p.insert("sparsify_s_per_forward", per_forward_s.into());
        p.insert("overhead_frac", frac.into());
        patterns.insert(key, p);
    }
    let mut j = Json::obj();
    j.insert("forward_s", forward_s.into());
    j.insert("act_rows", act_rows.into());
    j.insert("d_model", dims.d_model.into());
    j.insert("site_calls", site_calls.into());
    j.insert("threads", threads.into());
    j.insert("patterns", patterns);
    match std::fs::write(OVERHEAD_BENCH_FILE, j.pretty()) {
        Ok(()) => println!("wrote {OVERHEAD_BENCH_FILE}"),
        Err(e) => eprintln!("could not write {OVERHEAD_BENCH_FILE}: {e}"),
    }
}
