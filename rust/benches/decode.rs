//! Native decode-engine benchmarks -> `BENCH_decode.json`.
//!
//! Measures the numbers the paper's serving argument turns on, from a
//! *real* decode loop (seeded synthetic model, no PJRT, no artifacts):
//!
//! - prefill vs decode tokens/sec;
//! - per-step latency at several context lengths, for the KV-cached step
//!   AND the full-context baseline (one whole-row forward per token, the
//!   PJRT path's semantics) — the cached step must not inherit the
//!   baseline's growth with context;
//! - measured activation bytes per step: dense-equivalent vs what the
//!   compressed-domain path actually moved (packed payload + raw `u32`
//!   metadata words).
//!
//! `tools/check_bench_json.py` gates the emitted schema, including
//! `full_step_growth > cached_step_growth`.

use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity};
use nmsparse::sparsity::Pattern;
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::json::Json;
use nmsparse::util::prng::Rng;

fn main() {
    let mut suite = BenchSuite::new("decode");
    suite.target_time_s = 0.6;
    suite.samples = 10;

    let cfg = EngineConfig {
        vocab: 160,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        ffn: 256,
        max_seq: 128,
    };
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut engine =
        NativeEngine::synthetic(&cfg, 7, NativeSparsity::act(pattern)).expect("engine");
    let mut kv = engine.new_cache();
    let mut rng = Rng::new(11);
    let row: Vec<u32> = (0..cfg.max_seq).map(|_| rng.range(3, cfg.vocab) as u32).collect();

    // ---- prefill throughput ----
    let prefill_len = 64usize;
    suite.bench_with_items(
        &format!("decode/prefill {prefill_len} tokens (tokens)"),
        Some(prefill_len as f64),
        || {
            kv.reset();
            engine.prefill(&mut kv, &row[..prefill_len]).unwrap();
        },
    );
    let prefill_tps = suite.rate_of(&format!("decode/prefill {prefill_len} tokens (tokens)"));

    // ---- decode throughput (prefill 8, generate 32, KV-cached) ----
    suite.bench_with_items("decode/generate 32 tokens after 8 (tokens)", Some(32.0), || {
        let out = engine.generate_greedy(&mut kv, &row[..8], 32, &[]).unwrap();
        std::hint::black_box(out);
    });
    let decode_tps = suite.rate_of("decode/generate 32 tokens after 8 (tokens)");

    // ---- per-step latency vs context: cached step vs full-context ----
    let contexts = [8usize, 32, 96];
    let mut cached_ms = Vec::new();
    let mut full_ms = Vec::new();
    for &ctx in &contexts {
        // Cached: prebuild the cache once, truncate back before each
        // timed step so every iteration decodes at exactly `ctx`.
        kv.reset();
        engine.prefill(&mut kv, &row[..ctx]).unwrap();
        let name = format!("decode/cached step @ ctx {ctx} (tokens)");
        suite.bench_with_items(&name, Some(1.0), || {
            kv.truncate(ctx);
            engine.step(&mut kv, row[ctx]).unwrap();
        });
        cached_ms.push(step_ms(&suite, &name));
        // Full-context baseline: one whole-row forward per token.
        let name = format!("decode/full-context step @ ctx {ctx} (tokens)");
        suite.bench_with_items(&name, Some(1.0), || {
            engine.full_context(&mut kv, &row[..ctx]).unwrap();
        });
        full_ms.push(step_ms(&suite, &name));
    }

    // ---- measured bytes per step (packed vs dense-equivalent) ----
    engine.reset_stats();
    kv.reset();
    engine.prefill(&mut kv, &row[..32]).unwrap();
    let stats = engine.stats();
    let dense_bytes_per_step = stats.dense_activation_bytes as f64 / stats.steps as f64;
    let moved_bytes_per_step = stats.moved_activation_bytes as f64 / stats.steps as f64;

    // ---- report ----
    let cached_growth = cached_ms.last().unwrap() / cached_ms.first().unwrap().max(1e-9);
    let full_growth = full_ms.last().unwrap() / full_ms.first().unwrap().max(1e-9);
    println!(
        "decode: step growth ctx {}->{}: cached {:.2}x vs full-context {:.2}x | \
         bytes/step {:.0} -> {:.0} ({:.2}x reduction)",
        contexts[0],
        contexts[contexts.len() - 1],
        cached_growth,
        full_growth,
        dense_bytes_per_step,
        moved_bytes_per_step,
        stats.bytes_reduction(),
    );

    let mut j = Json::obj();
    j.insert("suite", "decode".into());
    j.insert("backend", "synthetic".into());
    j.insert("pattern", pattern.to_string().as_str().into());
    j.insert("method", "ACT".into());
    let mut m = Json::obj();
    m.insert("vocab", (cfg.vocab as f64).into());
    m.insert("d_model", (cfg.d_model as f64).into());
    m.insert("n_layers", (cfg.n_layers as f64).into());
    m.insert("ffn", (cfg.ffn as f64).into());
    m.insert("max_seq", (cfg.max_seq as f64).into());
    j.insert("model", m);
    j.insert("prefill_tokens_per_sec", prefill_tps.unwrap_or(0.0).into());
    j.insert("decode_tokens_per_sec", decode_tps.unwrap_or(0.0).into());
    let mut ctx_arr = Vec::new();
    for (i, &ctx) in contexts.iter().enumerate() {
        let mut e = Json::obj();
        e.insert("context", (ctx as f64).into());
        e.insert("cached_step_ms", cached_ms[i].into());
        e.insert("full_step_ms", full_ms[i].into());
        ctx_arr.push(e);
    }
    j.insert("contexts", Json::Arr(ctx_arr));
    j.insert("cached_step_growth", cached_growth.into());
    j.insert("full_step_growth", full_growth.into());
    j.insert("dense_bytes_per_step", dense_bytes_per_step.into());
    j.insert("packed_bytes_per_step", moved_bytes_per_step.into());
    j.insert("bytes_reduction", (dense_bytes_per_step / moved_bytes_per_step.max(1e-9)).into());
    // Only a complete run writes the dump — a --filter'd run would emit
    // zeros that the schema gate rightly rejects.
    let complete = cached_ms.iter().chain(&full_ms).all(|ms| *ms > 0.0)
        && prefill_tps.is_some()
        && decode_tps.is_some();
    if complete {
        match std::fs::write("BENCH_decode.json", j.pretty()) {
            Ok(()) => println!("wrote BENCH_decode.json"),
            Err(e) => eprintln!("could not write BENCH_decode.json: {e}"),
        }
    } else {
        println!("decode: filtered run — skipping BENCH_decode.json");
    }

    suite.finish();
}

/// Mean per-iteration time of a named benchmark, in milliseconds.
fn step_ms(suite: &BenchSuite, name: &str) -> f64 {
    suite
        .results()
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.stats.mean_s * 1e3)
        .unwrap_or(0.0)
}
