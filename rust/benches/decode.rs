//! Native decode-engine benchmarks -> `BENCH_decode.json`.
//!
//! Measures the numbers the paper's serving argument turns on, from a
//! *real* decode loop (seeded synthetic model, no PJRT, no artifacts):
//!
//! - prefill vs decode tokens/sec;
//! - blocked prefill (`prefill_block_grid`): the same prompt ingested at
//!   several block sizes, block 0 being the per-token oracle — every
//!   blocked variant is asserted bitwise logits-identical to the oracle
//!   before timing, so the grid measures wall time of a computation
//!   pinned identical (DESIGN.md §2.13);
//! - per-step latency at several context lengths, for the KV-cached step
//!   AND the full-context baseline (one whole-row forward per token, the
//!   PJRT path's semantics) — the cached step must not inherit the
//!   baseline's growth with context;
//! - batched vs sequential decode: `step_batch` over K concurrent lanes
//!   against K per-session `step` loops — the amortization the batched
//!   session-stepping API exists for (one weight-row stream per step
//!   instead of one per lane); the two are asserted bitwise-identical
//!   before timing;
//! - threads × lanes grid: the same batched tick at worker-pool widths
//!   1/2/4 across 1/4/16 lanes (`thread_grid` in the JSON) — each cell is
//!   asserted bitwise logits-identical to the 1-thread run before timing,
//!   so the grid measures wall time of a computation pinned identical;
//! - measured activation bytes per step: dense-equivalent vs what the
//!   compressed-domain path actually moved (packed payload + raw `u32`
//!   metadata words).
//!
//! `tools/check_bench_json.py` gates the emitted schema, including
//! `full_step_growth > cached_step_growth`, batched ≥ sequential
//! tok/s at batch ≥ 4, and threads=4 ≥ threads=1 tok/s at lanes ≥ 4.

use nmsparse::engine::{EngineConfig, NativeEngine, NativeSparsity, SessionKvPool, StepBatch};
use nmsparse::sparsity::Pattern;
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::json::Json;
use nmsparse::util::prng::Rng;
use nmsparse::util::trace::{self, TraceLevel};

fn main() {
    let mut suite = BenchSuite::new("decode");
    suite.target_time_s = 0.6;
    suite.samples = 10;

    let cfg = EngineConfig {
        vocab: 160,
        d_model: 128,
        n_layers: 2,
        n_heads: 4,
        ffn: 256,
        max_seq: 128,
    };
    let pattern = Pattern::NM { n: 8, m: 16 };
    let mut engine =
        NativeEngine::synthetic(&cfg, 7, NativeSparsity::act(pattern)).expect("engine");
    let mut pool = engine.new_kv_pool();
    let mut kv = pool.new_cache();
    let mut rng = Rng::new(11);
    let row: Vec<u32> = (0..cfg.max_seq).map(|_| rng.range(3, cfg.vocab) as u32).collect();

    // ---- prefill throughput ----
    let prefill_len = 64usize;
    suite.bench_with_items(
        &format!("decode/prefill {prefill_len} tokens (tokens)"),
        Some(prefill_len as f64),
        || {
            kv.reset(&mut pool);
            engine.prefill(&mut kv, &mut pool, &row[..prefill_len]).unwrap();
        },
    );
    let prefill_tps = suite.rate_of(&format!("decode/prefill {prefill_len} tokens (tokens)"));

    // ---- blocked prefill: tokens/sec vs block size ----
    // Block 0 is the per-token oracle. Pin every blocked variant bitwise
    // logits-identical to it on the same prompt before timing anything.
    let prefill_blocks = [0usize, 4, 16, 64];
    let mut prefill_rows = Vec::new();
    {
        kv.reset(&mut pool);
        engine.prefill(&mut kv, &mut pool, &row[..prefill_len]).unwrap();
        let want: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
        for &block in &prefill_blocks[1..] {
            kv.reset(&mut pool);
            engine.prefill_blocked(&mut kv, &mut pool, &row[..prefill_len], block).unwrap();
            let got: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
            assert_eq!(got, want, "block {block} changed prefill logit bits");
        }
    }
    for &block in &prefill_blocks {
        let name = format!("decode/prefill {prefill_len} tokens, block {block} (tokens)");
        suite.bench_with_items(&name, Some(prefill_len as f64), || {
            kv.reset(&mut pool);
            if block == 0 {
                engine.prefill(&mut kv, &mut pool, &row[..prefill_len]).unwrap();
            } else {
                engine.prefill_blocked(&mut kv, &mut pool, &row[..prefill_len], block).unwrap();
            }
        });
        let tps = suite.rate_of(&name).unwrap_or(0.0);
        println!("decode: prefill block {block}: {tps:.0} tok/s");
        prefill_rows.push((block, tps));
    }

    // ---- decode throughput (prefill 8, generate 32, KV-cached) ----
    suite.bench_with_items("decode/generate 32 tokens after 8 (tokens)", Some(32.0), || {
        let out = engine.generate_greedy(&mut kv, &mut pool, &row[..8], 32, &[]).unwrap();
        std::hint::black_box(out);
    });
    let decode_tps = suite.rate_of("decode/generate 32 tokens after 8 (tokens)");

    // ---- per-step latency vs context: cached step vs full-context ----
    let contexts = [8usize, 32, 96];
    let mut cached_ms = Vec::new();
    let mut full_ms = Vec::new();
    for &ctx in &contexts {
        // Cached: prebuild the cache once, truncate back before each
        // timed step so every iteration decodes at exactly `ctx`.
        kv.reset(&mut pool);
        engine.prefill(&mut kv, &mut pool, &row[..ctx]).unwrap();
        let name = format!("decode/cached step @ ctx {ctx} (tokens)");
        suite.bench_with_items(&name, Some(1.0), || {
            kv.truncate(&mut pool, ctx);
            engine.step(&mut kv, &mut pool, row[ctx]).unwrap();
        });
        cached_ms.push(step_ms(&suite, &name));
        // Full-context baseline: one whole-row forward per token.
        let name = format!("decode/full-context step @ ctx {ctx} (tokens)");
        suite.bench_with_items(&name, Some(1.0), || {
            engine.full_context(&mut kv, &mut pool, &row[..ctx]).unwrap();
        });
        full_ms.push(step_ms(&suite, &name));
    }

    // ---- batched vs sequential session stepping ----
    // K concurrent lanes at ragged contexts: one step_batch per step vs
    // K per-session step calls. Same tokens, same caches, same math —
    // the batched form amortizes each weight row across lanes.
    let lane_counts = [1usize, 4, 8];
    let mut batched_rows = Vec::new();
    for &lanes in &lane_counts {
        let mut sessions = SessionKvPool::new(lanes.max(2));
        let mut batch = StepBatch::new();
        let ctx_of = |i: usize| 12 + 9 * i; // ragged lane contexts
        for i in 0..lanes {
            let slot = sessions.get_or_create(&mut pool, i as u64 + 1);
            slot.kv.reset(&mut pool);
            engine.prefill(&mut slot.kv, &mut pool, &row[..ctx_of(i)]).unwrap();
        }
        // Bitwise identity before timing: one batched step == per-lane
        // sequential steps on separate caches.
        {
            batch.clear();
            for i in 0..lanes {
                batch.push(i as u64 + 1, row[ctx_of(i)]);
            }
            engine.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
            for i in 0..lanes {
                let mut check_kv = pool.new_cache();
                engine.prefill(&mut check_kv, &mut pool, &row[..ctx_of(i)]).unwrap();
                engine.step(&mut check_kv, &mut pool, row[ctx_of(i)]).unwrap();
                let want: Vec<u32> = engine.logits().iter().map(|v| v.to_bits()).collect();
                let got: Vec<u32> = batch.logits(i).iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want, "lane {i}: batched != sequential logits");
                check_kv.reset(&mut pool);
            }
            for i in 0..lanes {
                let slot = sessions.get_mut(i as u64 + 1).unwrap();
                slot.kv.truncate(&mut pool, ctx_of(i));
            }
        }
        let name = format!("decode/step_batch {lanes} lanes (tokens)");
        suite.bench_with_items(&name, Some(lanes as f64), || {
            batch.clear();
            for i in 0..lanes {
                let slot = sessions.get_mut(i as u64 + 1).unwrap();
                slot.kv.truncate(&mut pool, ctx_of(i));
                batch.push(i as u64 + 1, row[ctx_of(i)]);
            }
            engine.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
        });
        let batched_tps = suite.rate_of(&name).unwrap_or(0.0);
        let name = format!("decode/sequential {lanes} lanes (tokens)");
        suite.bench_with_items(&name, Some(lanes as f64), || {
            for i in 0..lanes {
                let slot = sessions.get_mut(i as u64 + 1).unwrap();
                slot.kv.truncate(&mut pool, ctx_of(i));
            }
            for i in 0..lanes {
                let slot = sessions.get_mut(i as u64 + 1).unwrap();
                engine.step(&mut slot.kv, &mut pool, row[ctx_of(i)]).unwrap();
            }
        });
        let sequential_tps = suite.rate_of(&name).unwrap_or(0.0);
        for i in 0..lanes {
            sessions.remove(&mut pool, i as u64 + 1);
        }
        println!(
            "decode: {lanes} lanes batched {batched_tps:.0} tok/s vs sequential \
             {sequential_tps:.0} tok/s ({:.2}x)",
            batched_tps / sequential_tps.max(1e-9),
        );
        batched_rows.push((lanes, batched_tps, sequential_tps));
    }

    // ---- threads x lanes grid: worker-pool scaling of step_batch ----
    // The same batched tick at pool widths 1/2/4. Before timing a lane
    // count, pin that widening the pool does not change a single logit
    // bit (the weight-row partition gives each worker disjoint whole
    // output rows — DESIGN.md §2.11), so the grid times a computation
    // already proven identical.
    let thread_counts = [1usize, 2, 4];
    let grid_lanes = [1usize, 4, 16];
    let mut grid_rows = Vec::new();
    for &lanes in &grid_lanes {
        let mut sessions = SessionKvPool::new(lanes.max(2));
        let mut batch = StepBatch::new();
        let ctx_of = |i: usize| 10 + 5 * (i % 7); // ragged lane contexts
        for i in 0..lanes {
            let slot = sessions.get_or_create(&mut pool, i as u64 + 1);
            slot.kv.reset(&mut pool);
            engine.prefill(&mut slot.kv, &mut pool, &row[..ctx_of(i)]).unwrap();
        }
        let mut want: Vec<Vec<u32>> = Vec::new();
        for &threads in &thread_counts {
            engine.set_threads(threads);
            batch.clear();
            for i in 0..lanes {
                let slot = sessions.get_mut(i as u64 + 1).unwrap();
                slot.kv.truncate(&mut pool, ctx_of(i));
                batch.push(i as u64 + 1, row[ctx_of(i)]);
            }
            engine.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
            let got: Vec<Vec<u32>> = (0..lanes)
                .map(|i| batch.logits(i).iter().map(|v| v.to_bits()).collect())
                .collect();
            if want.is_empty() {
                want = got;
            } else {
                assert_eq!(got, want, "{threads} threads changed step_batch logit bits");
            }
        }
        for &threads in &thread_counts {
            engine.set_threads(threads);
            let name = format!("decode/step_batch {lanes} lanes x {threads} threads (tokens)");
            suite.bench_with_items(&name, Some(lanes as f64), || {
                batch.clear();
                for i in 0..lanes {
                    let slot = sessions.get_mut(i as u64 + 1).unwrap();
                    slot.kv.truncate(&mut pool, ctx_of(i));
                    batch.push(i as u64 + 1, row[ctx_of(i)]);
                }
                engine.step_batch(&mut batch, &mut sessions, &mut pool).unwrap();
            });
            let tps = suite.rate_of(&name).unwrap_or(0.0);
            println!("decode: grid {lanes} lanes x {threads} threads: {tps:.0} tok/s");
            grid_rows.push((threads, lanes, tps));
        }
        for i in 0..lanes {
            sessions.remove(&mut pool, i as u64 + 1);
        }
    }
    engine.set_threads(1);

    // ---- per-phase breakdown: one traced prefill+decode pass ----
    // Metrics-level tracing on a separate pass (never inside the timed
    // closures above, which must measure the untraced hot path): prefill
    // 32 tokens, decode 64 more, snapshot the span aggregates.
    trace::set_level(TraceLevel::Metrics);
    trace::reset();
    let phase_t0 = std::time::Instant::now();
    kv.reset(&mut pool);
    engine.prefill(&mut kv, &mut pool, &row[..32]).unwrap();
    for i in 32..96 {
        engine.step(&mut kv, &mut pool, row[i]).unwrap();
    }
    let phase_wall_s = phase_t0.elapsed().as_secs_f64();
    trace::set_level(TraceLevel::Off);
    let phases = trace::snapshot();
    println!("decode: {}", phases.summary());

    // ---- measured bytes per step (packed vs dense-equivalent) ----
    engine.reset_stats();
    kv.reset(&mut pool);
    engine.prefill(&mut kv, &mut pool, &row[..32]).unwrap();
    let stats = engine.stats();
    let dense_bytes_per_step = stats.dense_activation_bytes as f64 / stats.steps as f64;
    let moved_bytes_per_step = stats.moved_activation_bytes as f64 / stats.steps as f64;

    // ---- report ----
    let cached_growth = cached_ms.last().unwrap() / cached_ms.first().unwrap().max(1e-9);
    let full_growth = full_ms.last().unwrap() / full_ms.first().unwrap().max(1e-9);
    println!(
        "decode: step growth ctx {}->{}: cached {:.2}x vs full-context {:.2}x | \
         bytes/step {:.0} -> {:.0} ({:.2}x reduction)",
        contexts[0],
        contexts[contexts.len() - 1],
        cached_growth,
        full_growth,
        dense_bytes_per_step,
        moved_bytes_per_step,
        stats.bytes_reduction(),
    );

    let mut j = Json::obj();
    j.insert("suite", "decode".into());
    j.insert("backend", "synthetic".into());
    j.insert("pattern", pattern.to_string().as_str().into());
    j.insert("method", "ACT".into());
    let mut m = Json::obj();
    m.insert("vocab", (cfg.vocab as f64).into());
    m.insert("d_model", (cfg.d_model as f64).into());
    m.insert("n_layers", (cfg.n_layers as f64).into());
    m.insert("ffn", (cfg.ffn as f64).into());
    m.insert("max_seq", (cfg.max_seq as f64).into());
    j.insert("model", m);
    j.insert("prefill_tokens_per_sec", prefill_tps.unwrap_or(0.0).into());
    j.insert("prefill_prompt_tokens", (prefill_len as f64).into());
    let mut pf_arr = Vec::new();
    for &(block, tps) in &prefill_rows {
        let mut e = Json::obj();
        e.insert("block", (block as f64).into());
        e.insert("tokens_per_sec", tps.into());
        pf_arr.push(e);
    }
    j.insert("prefill_block_grid", Json::Arr(pf_arr));
    j.insert("decode_tokens_per_sec", decode_tps.unwrap_or(0.0).into());
    let mut ctx_arr = Vec::new();
    for (i, &ctx) in contexts.iter().enumerate() {
        let mut e = Json::obj();
        e.insert("context", (ctx as f64).into());
        e.insert("cached_step_ms", cached_ms[i].into());
        e.insert("full_step_ms", full_ms[i].into());
        ctx_arr.push(e);
    }
    j.insert("contexts", Json::Arr(ctx_arr));
    let mut batch_arr = Vec::new();
    for &(lanes, btps, stps) in &batched_rows {
        let mut e = Json::obj();
        e.insert("batch", (lanes as f64).into());
        e.insert("batched_tokens_per_sec", btps.into());
        e.insert("sequential_tokens_per_sec", stps.into());
        e.insert("batched_speedup", (btps / stps.max(1e-9)).into());
        batch_arr.push(e);
    }
    j.insert("batched", Json::Arr(batch_arr));
    let mut grid_arr = Vec::new();
    for &(threads, lanes, tps) in &grid_rows {
        let mut e = Json::obj();
        e.insert("threads", (threads as f64).into());
        e.insert("lanes", (lanes as f64).into());
        e.insert("tokens_per_sec", tps.into());
        grid_arr.push(e);
    }
    j.insert("thread_grid", Json::Arr(grid_arr));
    j.insert("phases", phases.to_json(phase_wall_s));
    j.insert("cached_step_growth", cached_growth.into());
    j.insert("full_step_growth", full_growth.into());
    j.insert("dense_bytes_per_step", dense_bytes_per_step.into());
    j.insert("packed_bytes_per_step", moved_bytes_per_step.into());
    j.insert("bytes_reduction", (dense_bytes_per_step / moved_bytes_per_step.max(1e-9)).into());
    // Only a complete run writes the dump — a --filter'd run would emit
    // zeros that the schema gate rightly rejects.
    let complete = cached_ms.iter().chain(&full_ms).all(|ms| *ms > 0.0)
        && prefill_tps.is_some()
        && decode_tps.is_some()
        && prefill_rows.iter().all(|(_, t)| *t > 0.0)
        && batched_rows.iter().all(|(_, b, s)| *b > 0.0 && *s > 0.0)
        && grid_rows.iter().all(|(_, _, t)| *t > 0.0);
    if complete {
        match std::fs::write("BENCH_decode.json", j.pretty()) {
            Ok(()) => println!("wrote BENCH_decode.json"),
            Err(e) => eprintln!("could not write BENCH_decode.json: {e}"),
        }
    } else {
        println!("decode: filtered run — skipping BENCH_decode.json");
    }

    suite.finish();
}

/// Mean per-iteration time of a named benchmark, in milliseconds.
fn step_ms(suite: &BenchSuite, name: &str) -> f64 {
    suite
        .results()
        .iter()
        .find(|r| r.name == name)
        .map(|r| r.stats.mean_s * 1e3)
        .unwrap_or(0.0)
}
