//! Pure-rust substrate benchmarks: PRNG, JSON, tokenizer, N:M selection,
//! metadata codecs, quantization — the L3-side hot paths that must never
//! dominate the PJRT executable time.
//!
//! `cargo bench --offline -- substrate` (custom harness; criterion is not
//! available in the offline image — see util::bench).

use nmsparse::metadata::MaskCodec;
use nmsparse::sparsity::{nm, unstructured, Pattern};
use nmsparse::synthlang::vocab::Vocab;
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::json;
use nmsparse::util::prng::Rng;
use nmsparse::util::tensor::Tensor;

fn main() {
    let mut suite = BenchSuite::new("substrate");
    let mut rng = Rng::new(42);

    // ---- PRNG ----
    {
        let mut r = Rng::new(1);
        suite.bench_with_items("prng/next_u64 x1024", Some(1024.0), move || {
            let mut acc = 0u64;
            for _ in 0..1024 {
                acc ^= r.next_u64();
            }
            std::hint::black_box(acc);
        });
    }

    // ---- JSON ----
    {
        // A realistic task-file-shaped document.
        let mut obj = json::Json::obj();
        let mut examples = Vec::new();
        for i in 0..64 {
            let mut e = json::Json::obj();
            e.insert("context", (0..24usize).map(|x| x + i).collect::<Vec<_>>().into());
            e.insert("label", (i % 4).into());
            e.insert("text", format!("example number {i} with some text").into());
            examples.push(e);
        }
        obj.insert("examples", json::Json::Arr(examples));
        let text = obj.dump();
        let bytes = text.len() as f64;
        suite.bench_with_items("json/parse task-file (bytes)", Some(bytes), || {
            std::hint::black_box(json::parse(&text).unwrap());
        });
        let parsed = json::parse(&text).unwrap();
        suite.bench_with_items("json/dump task-file (bytes)", Some(bytes), || {
            std::hint::black_box(parsed.dump());
        });
    }

    // ---- tokenizer ----
    {
        let vocab = Vocab::synthlang();
        let sentence = "does the red fox live in the forest ? yes . the red fox eats berries .";
        let words = sentence.split_whitespace().count() as f64;
        suite.bench_with_items("tokenizer/encode (words)", Some(words), || {
            std::hint::black_box(vocab.encode(sentence).unwrap());
        });
        let ids = vocab.encode(sentence).unwrap();
        suite.bench_with_items("tokenizer/decode (tokens)", Some(ids.len() as f64), || {
            std::hint::black_box(vocab.decode(&ids));
        });
    }

    // ---- rust-native N:M selection (weight-pruning path) ----
    for (n, m) in [(2usize, 4usize), (8, 16), (16, 32)] {
        let h = 1024;
        let xs: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        suite.bench_with_items(
            &format!("sparsity/nm_mask {n}:{m} (elts)"),
            Some(h as f64),
            || {
                std::hint::black_box(nm::nm_mask(&xs, n, m));
            },
        );
    }
    {
        let h = 1024;
        let xs: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        suite.bench_with_items("sparsity/topk u50 (elts)", Some(h as f64), || {
            let mut v = xs.clone();
            unstructured::prune_row_magnitude(&mut v, 0.5);
            std::hint::black_box(v);
        });
    }
    {
        // Whole-tensor weight pruning, the WT-baseline bind-time cost.
        let w = Tensor::from_vec(
            &[512, 512],
            (0..512 * 512).map(|_| rng.normal() as f32).collect(),
        );
        suite.bench_with_items(
            "sparsity/prune_weight_tensor 512x512 8:16 (elts)",
            Some((512 * 512) as f64),
            || {
                let mut t = w.clone();
                nmsparse::sparsity::weightprune::prune_weight_tensor(
                    &mut t,
                    Pattern::NM { n: 8, m: 16 },
                );
                std::hint::black_box(t);
            },
        );
    }

    // ---- metadata codecs ----
    for codec in [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic] {
        let (n, m) = (8usize, 16usize);
        let masks: Vec<Vec<bool>> = (0..256)
            .map(|_| {
                let idx = rng.sample_indices(m, n);
                let mut mk = vec![false; m];
                for i in idx {
                    mk[i] = true;
                }
                mk
            })
            .collect();
        let elts = (256 * m) as f64;
        suite.bench_with_items(
            &format!("metadata/encode {codec:?} 8:16 (elts)"),
            Some(elts),
            || {
                std::hint::black_box(codec.encode_blocks(&masks, n, m));
            },
        );
        let (bytes, _) = codec.encode_blocks(&masks, n, m);
        suite.bench_with_items(
            &format!("metadata/decode {codec:?} 8:16 (elts)"),
            Some(elts),
            || {
                std::hint::black_box(codec.decode_blocks(&bytes, 256, n, m).unwrap());
            },
        );
    }

    // ---- quantization ----
    {
        let w = Tensor::from_vec(
            &[256, 512],
            (0..256 * 512).map(|_| rng.normal() as f32 * 0.05).collect(),
        );
        suite.bench_with_items(
            "quant/fake_quant_int8 256x512 (elts)",
            Some((256 * 512) as f64),
            || {
                let mut t = w.clone();
                std::hint::black_box(nmsparse::quant::fake_quant_int8(&mut t, 8));
            },
        );
    }

    suite.finish();
}
