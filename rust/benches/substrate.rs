//! Pure-rust substrate benchmarks: PRNG, JSON, tokenizer, the fused
//! sparsification pipeline vs the seed per-row loop, metadata codecs,
//! quantization — the L3-side hot paths that must never dominate the PJRT
//! executable time.
//!
//! `cargo bench --offline -- substrate` (custom harness; criterion is not
//! available in the offline image — see util::bench). Writes
//! `BENCH_sparsity.json` with the per-pattern rows/sec of the seed loop,
//! the fused per-row pass and the row-parallel batch driver.

use nmsparse::metadata::{mask_to_word, word_to_mask, MaskCodec};
use nmsparse::sparsity::{pipeline, PackedNM, Pattern, Scratch, Sparsifier};
use nmsparse::synthlang::vocab::Vocab;
use nmsparse::util::bench::BenchSuite;
use nmsparse::util::json::{self, Json};
use nmsparse::util::prng::Rng;
use nmsparse::util::tensor::Tensor;
use nmsparse::util::threadpool;

fn main() {
    let mut suite = BenchSuite::new("substrate");
    let mut rng = Rng::new(42);

    // ---- PRNG ----
    {
        let mut r = Rng::new(1);
        suite.bench_with_items("prng/next_u64 x1024", Some(1024.0), move || {
            let mut acc = 0u64;
            for _ in 0..1024 {
                acc ^= r.next_u64();
            }
            std::hint::black_box(acc);
        });
    }

    // ---- JSON ----
    {
        // A realistic task-file-shaped document.
        let mut obj = json::Json::obj();
        let mut examples = Vec::new();
        for i in 0..64 {
            let mut e = json::Json::obj();
            e.insert("context", (0..24usize).map(|x| x + i).collect::<Vec<_>>().into());
            e.insert("label", (i % 4).into());
            e.insert("text", format!("example number {i} with some text").into());
            examples.push(e);
        }
        obj.insert("examples", json::Json::Arr(examples));
        let text = obj.dump();
        let bytes = text.len() as f64;
        suite.bench_with_items("json/parse task-file (bytes)", Some(bytes), || {
            std::hint::black_box(json::parse(&text).unwrap());
        });
        let parsed = json::parse(&text).unwrap();
        suite.bench_with_items("json/dump task-file (bytes)", Some(bytes), || {
            std::hint::black_box(parsed.dump());
        });
    }

    // ---- tokenizer ----
    {
        let vocab = Vocab::synthlang();
        let sentence = "does the red fox live in the forest ? yes . the red fox eats berries .";
        let words = sentence.split_whitespace().count() as f64;
        suite.bench_with_items("tokenizer/encode (words)", Some(words), || {
            std::hint::black_box(vocab.encode(sentence).unwrap());
        });
        let ids = vocab.encode(sentence).unwrap();
        suite.bench_with_items("tokenizer/decode (tokens)", Some(ids.len() as f64), || {
            std::hint::black_box(vocab.decode(&ids));
        });
    }

    // ---- fused sparsification pipeline vs the seed per-row loop ----
    // The tentpole comparison: the seed path (three allocating passes with
    // an O(m²) rank loop per block, preserved as pipeline::reference_*)
    // against the fused Sparsifier (single pass, O(m) nth-element select,
    // reusable scratch) and its row-parallel batch driver.
    let (rows, h) = (256usize, 1024usize);
    let threads = threadpool::default_threads();
    let sparsity_patterns = ["2:4", "8:16", "16:32", "u50"];
    {
        let x = Tensor::from_vec(
            &[rows, h],
            (0..rows * h).map(|_| rng.normal() as f32).collect(),
        );
        for key in sparsity_patterns {
            let pattern = Pattern::parse(key).unwrap();
            let sp = Sparsifier::new(pattern);
            {
                let mut buf = x.data.clone();
                suite.bench_with_items(
                    &format!("sparsity/seed per-row {key} (rows)"),
                    Some(rows as f64),
                    || {
                        buf.copy_from_slice(&x.data);
                        for row in buf.chunks_exact_mut(h) {
                            pipeline::reference_row_prune(row, pattern);
                        }
                        std::hint::black_box(&buf);
                    },
                );
            }
            {
                let mut buf = x.data.clone();
                let mut scratch = Scratch::new();
                suite.bench_with_items(
                    &format!("sparsity/fused per-row {key} (rows)"),
                    Some(rows as f64),
                    || {
                        buf.copy_from_slice(&x.data);
                        for row in buf.chunks_exact_mut(h) {
                            sp.sparsify_row(row, &mut scratch);
                        }
                        std::hint::black_box(&buf);
                    },
                );
            }
            {
                let mut t = x.clone();
                suite.bench_with_items(
                    &format!("sparsity/fused batch {key} (rows)"),
                    Some(rows as f64),
                    || {
                        t.data.copy_from_slice(&x.data);
                        sp.sparsify_batch(&mut t, threads);
                        std::hint::black_box(&t);
                    },
                );
            }
        }
    }
    {
        // Whole-tensor weight pruning, the WT-baseline bind-time cost (now
        // routed through the fused pipeline's batch driver).
        let w = Tensor::from_vec(
            &[512, 512],
            (0..512 * 512).map(|_| rng.normal() as f32).collect(),
        );
        suite.bench_with_items(
            "sparsity/prune_weight_tensor 512x512 8:16 (elts)",
            Some((512 * 512) as f64),
            || {
                let mut t = w.clone();
                nmsparse::sparsity::weightprune::prune_weight_tensor(
                    &mut t,
                    Pattern::NM { n: 8, m: 16 },
                );
                std::hint::black_box(t);
            },
        );
    }

    // ---- packed activation streams (compressed-domain path) ----
    // Pack/unpack bandwidth, packed-vs-dense GEMV and word-vs-bit codec
    // throughput per pattern; written to BENCH_packed.json below. The
    // acceptance gate requires the word-level codec roundtrip >= 5x the
    // seed per-bit path at 8:16.
    let packed_patterns = ["2:4", "4:8", "8:16", "16:32", "u50"];
    let dense_row_bytes = (h * 4) as f64;
    let mut packed_footprints: Vec<(String, f64)> = Vec::new();
    {
        let x = Tensor::from_vec(
            &[rows, h],
            (0..rows * h).map(|_| rng.normal() as f32).collect(),
        );
        let v: Vec<f32> = (0..h).map(|_| rng.normal() as f32).collect();
        for key in packed_patterns {
            let pattern = Pattern::parse(key).unwrap();
            let sp = Sparsifier::new(pattern);
            let bytes_total = (rows * h * 4) as f64;
            {
                let mut packed = PackedNM::new(pattern, h);
                let mut scratch = Scratch::new();
                suite.bench_with_items(
                    &format!("packed/pack {key} (bytes)"),
                    Some(bytes_total),
                    || {
                        sp.pack(&x, &mut packed, &mut scratch);
                        std::hint::black_box(&packed);
                    },
                );
            }
            {
                let mut packed = PackedNM::new(pattern, h);
                suite.bench_with_items(
                    &format!("packed/pack batch {key} (bytes)"),
                    Some(bytes_total),
                    || {
                        sp.pack_batch(&x, &mut packed, threads);
                        std::hint::black_box(&packed);
                    },
                );
            }
            let mut packed = PackedNM::new(pattern, h);
            let mut scratch = Scratch::new();
            sp.pack(&x, &mut packed, &mut scratch);
            {
                let mut y = Tensor::zeros(&[rows, h]);
                suite.bench_with_items(
                    &format!("packed/unpack {key} (bytes)"),
                    Some(bytes_total),
                    || {
                        packed.decode_into(&mut y, 1);
                        std::hint::black_box(&y);
                    },
                );
            }
            {
                let mut out = vec![0.0f32; rows];
                suite.bench_with_items(
                    &format!("packed/gemv {key} (rows)"),
                    Some(rows as f64),
                    || {
                        packed.matvec_into(&v, &mut out, 1);
                        std::hint::black_box(&out);
                    },
                );
                // Dense GEMV over the decoded (zero-carrying) matrix.
                let dense = packed.to_dense();
                suite.bench_with_items(
                    &format!("packed/gemv dense {key} (rows)"),
                    Some(rows as f64),
                    || {
                        for (r, o) in out.iter_mut().enumerate() {
                            *o = dense.row(r).iter().zip(&v).map(|(a, b)| a * b).sum();
                        }
                        std::hint::black_box(&out);
                    },
                );
            }
            let codec = if matches!(pattern, Pattern::NM { .. }) {
                MaskCodec::Combinadic
            } else {
                MaskCodec::Bitmap
            };
            packed_footprints.push((key.to_string(), packed.measured_bytes_per_row(codec)));
        }
    }

    // ---- metadata codecs: word path vs the seed per-bit path ----
    for codec in [MaskCodec::Bitmap, MaskCodec::IndexList, MaskCodec::Combinadic] {
        let (n, m) = (8usize, 16usize);
        let words: Vec<u32> = (0..256)
            .map(|_| {
                let idx = rng.sample_indices(m, n);
                let mut mk = vec![false; m];
                for i in idx {
                    mk[i] = true;
                }
                mask_to_word(&mk)
            })
            .collect();
        let masks: Vec<Vec<bool>> = words.iter().map(|&w| word_to_mask(w, m)).collect();
        let blocks = words.len() as f64;
        suite.bench_with_items(
            &format!("metadata/word roundtrip {codec:?} 8:16 (blocks)"),
            Some(blocks),
            || {
                let (bytes, _) = codec.encode_words(&words, n, m);
                std::hint::black_box(codec.decode_words(&bytes, words.len(), n, m).unwrap());
            },
        );
        suite.bench_with_items(
            &format!("metadata/bit roundtrip {codec:?} 8:16 (blocks)"),
            Some(blocks),
            || {
                let (bytes, _) = codec.reference_encode_blocks(&masks, n, m);
                std::hint::black_box(
                    codec.reference_decode_blocks(&bytes, masks.len(), n, m).unwrap(),
                );
            },
        );
    }

    // ---- quantization ----
    {
        let w = Tensor::from_vec(
            &[256, 512],
            (0..256 * 512).map(|_| rng.normal() as f32 * 0.05).collect(),
        );
        suite.bench_with_items(
            "quant/fake_quant_int8 256x512 (elts)",
            Some((256 * 512) as f64),
            || {
                let mut t = w.clone();
                std::hint::black_box(nmsparse::quant::fake_quant_int8(&mut t, 8));
            },
        );
    }

    // ---- machine-readable sparsity report (BENCH_sparsity.json) ----
    // Per-pattern rows/sec for the seed loop vs the fused paths, plus the
    // speedup ratios the acceptance gate checks (fused batch ≥ 3x seed at
    // 8:16). Skipped when a --filter hid the sparsity benches.
    {
        let mut patterns = Json::obj();
        let mut have_any = false;
        for key in sparsity_patterns {
            let seed = suite.rate_of(&format!("sparsity/seed per-row {key} (rows)"));
            let fused_row = suite.rate_of(&format!("sparsity/fused per-row {key} (rows)"));
            let fused_batch = suite.rate_of(&format!("sparsity/fused batch {key} (rows)"));
            if let (Some(seed), Some(fused_row), Some(fused_batch)) =
                (seed, fused_row, fused_batch)
            {
                have_any = true;
                let mut p = Json::obj();
                p.insert("seed_rows_per_sec", seed.into());
                p.insert("fused_row_rows_per_sec", fused_row.into());
                p.insert("fused_batch_rows_per_sec", fused_batch.into());
                p.insert("fused_row_speedup_vs_seed", (fused_row / seed).into());
                p.insert("fused_batch_speedup_vs_seed", (fused_batch / seed).into());
                patterns.insert(key, p);
            }
        }
        if have_any {
            let mut j = suite.to_json();
            j.insert("rows", rows.into());
            j.insert("hidden", h.into());
            j.insert("threads", threads.into());
            j.insert("patterns", patterns);
            match std::fs::write("BENCH_sparsity.json", j.pretty()) {
                Ok(()) => println!("wrote BENCH_sparsity.json"),
                Err(e) => eprintln!("could not write BENCH_sparsity.json: {e}"),
            }
        }
    }

    // ---- machine-readable packed report (BENCH_packed.json) ----
    // Per-pattern measured bytes-per-row of the compressed activation
    // stream (kept values + encoded metadata), pack/unpack bandwidth,
    // packed-vs-dense GEMV and the word-vs-bit codec speedup the
    // acceptance gate checks (>= 5x at 8:16). `nmsparse table table6` and
    // `examples/hw_breakeven.rs` consume this file in place of the
    // theoretical bits_per_element story. Skipped when a --filter hid the
    // packed benches.
    {
        let mut patterns = Json::obj();
        let mut have_any = false;
        let codec_word = suite.rate_of("metadata/word roundtrip Combinadic 8:16 (blocks)");
        let codec_bit = suite.rate_of("metadata/bit roundtrip Combinadic 8:16 (blocks)");
        for (key, packed_bytes_per_row) in &packed_footprints {
            let pack = suite.rate_of(&format!("packed/pack {key} (bytes)"));
            let pack_batch = suite.rate_of(&format!("packed/pack batch {key} (bytes)"));
            let unpack = suite.rate_of(&format!("packed/unpack {key} (bytes)"));
            let gemv = suite.rate_of(&format!("packed/gemv {key} (rows)"));
            let gemv_dense = suite.rate_of(&format!("packed/gemv dense {key} (rows)"));
            if let (Some(pack), Some(unpack), Some(gemv), Some(gemv_dense)) =
                (pack, unpack, gemv, gemv_dense)
            {
                have_any = true;
                let mut p = Json::obj();
                p.insert("dense_bytes_per_row", dense_row_bytes.into());
                p.insert("packed_bytes_per_row", (*packed_bytes_per_row).into());
                p.insert(
                    "measured_bandwidth_reduction",
                    (dense_row_bytes / packed_bytes_per_row.max(1e-12)).into(),
                );
                p.insert("pack_gbps", (pack / 1e9).into());
                if let Some(pb) = pack_batch {
                    p.insert("pack_batch_gbps", (pb / 1e9).into());
                }
                p.insert("unpack_gbps", (unpack / 1e9).into());
                p.insert("packed_gemv_rows_per_sec", gemv.into());
                p.insert("dense_gemv_rows_per_sec", gemv_dense.into());
                p.insert("packed_gemv_speedup", (gemv / gemv_dense).into());
                if key == "8:16" {
                    if let (Some(w), Some(b)) = (codec_word, codec_bit) {
                        p.insert("codec_word_blocks_per_sec", w.into());
                        p.insert("codec_bit_blocks_per_sec", b.into());
                        p.insert("codec_word_speedup", (w / b).into());
                    }
                }
                patterns.insert(key, p);
            }
        }
        if have_any {
            let mut j = suite.to_json();
            j.insert("rows", rows.into());
            j.insert("hidden", h.into());
            j.insert("threads", threads.into());
            j.insert("patterns", patterns);
            match std::fs::write("BENCH_packed.json", j.pretty()) {
                Ok(()) => println!("wrote BENCH_packed.json"),
                Err(e) => eprintln!("could not write BENCH_packed.json: {e}"),
            }
        }
    }

    suite.finish();
}
