#!/usr/bin/env python3
"""Validator for the Chrome trace-event JSON written by `--trace <path>`
(rust/src/util/trace.rs, `write_chrome_trace`).

The exporter emits complete ("ph": "X") events with fractional-
microsecond timestamps from one process-wide monotonic epoch, sorted by
(tid, ts). This script fails CI loudly when an export violates that
contract:

- every event carries the required keys with the right types, a phase
  name from the DESIGN.md §2.14 taxonomy, and non-negative ts/dur;
- timestamps are monotone per tid (the exporter sorts by (tid, ts));
- events on one tid are properly paired: intervals either nest or are
  disjoint — a child span closing after its parent means a begin/end
  pairing bug (spans are recorded at guard drop, so a parent always
  encloses its children; ring eviction only removes whole events and
  cannot break laminarity). queue_wait spans are exempt: their start is
  synthesized (admission time, usually on another thread), so they
  overlap freely — the exporter parks them on a separate track
  (tid + WAIT_TRACK_OFFSET) and this script only checks them for
  monotone timestamps.

Usage: tools/check_trace_json.py <trace.json> [...]
       tools/check_trace_json.py --self-test
"""

import json
import sys
from pathlib import Path

KNOWN_PHASES = frozenset((
    "queue_wait", "tick_build", "prefill_block",
    "site_matmul_q", "site_matmul_k", "site_matmul_v", "site_matmul_o",
    "site_matmul_gate", "site_matmul_up", "site_matmul_down",
    "sparsify", "pack", "attention", "lm_head", "reply", "engine_build",
))

# One exported nanosecond of slack for float round-off (timestamps are
# u64 nanoseconds divided by 1e3 on export).
EPS_US = 1e-3


def err(path, msg):
    print(f"check_trace_json: {path}: {msg}", file=sys.stderr)
    return 1


def check_event(e, path, ctx):
    bad = 0
    if not isinstance(e, dict):
        return err(path, f"{ctx} is not an object")
    for key, types in (("name", str), ("cat", str), ("ph", str),
                       ("ts", (int, float)), ("dur", (int, float)),
                       ("pid", (int, float)), ("tid", (int, float))):
        if key not in e:
            return err(path, f"{ctx}: missing required key '{key}'")
        if not isinstance(e[key], types):
            return err(path, f"{ctx}: key '{key}' has type "
                             f"{type(e[key]).__name__}")
    if e["ph"] != "X":
        bad |= err(path, f"{ctx}: ph '{e['ph']}' != 'X' — the exporter only "
                         f"writes complete events")
    if e["name"] not in KNOWN_PHASES:
        bad |= err(path, f"{ctx}: unknown phase name '{e['name']}' "
                         f"(span taxonomy: DESIGN.md §2.14)")
    if e["ts"] < 0 or e["dur"] < 0:
        bad |= err(path, f"{ctx}: negative ts/dur ({e['ts']}, {e['dur']})")
    args = e.get("args")
    if not isinstance(args, dict) or not isinstance(args.get("id"),
                                                    (int, float)):
        bad |= err(path, f"{ctx}: missing numeric args.id (request-scoped "
                         f"span id, 0 when unknown)")
    return bad


def check_track(tid, events, path):
    """Monotone timestamps and proper nesting for one tid's events.

    Events arrive in file order; the monotone-ts gate runs on exactly
    that order. The nesting sweep re-orders ties on (ts, -dur) first: a
    parent sharing its first child's start timestamp (coarse clock) must
    be swept before the child or laminar nesting reads as a straddle.
    With outermost-first ties, a stack of open interval ends detects
    partial overlap: when a new event starts inside an open interval it
    must also end inside it.
    """
    bad = 0
    prev_ts = -1.0
    for i, e in enumerate(events):
        if e["ts"] < prev_ts:
            bad |= err(path, f"tid {tid} event[{i}] ({e['name']}): ts "
                             f"{e['ts']} before previous {prev_ts} — "
                             f"per-tid timestamps must be monotone")
        prev_ts = e["ts"]
    stack = []  # open interval end timestamps, innermost last
    for i, e in enumerate(sorted(events, key=lambda e: (e["ts"], -e["dur"]))):
        ctx = f"tid {tid} span ({e['name']} @ {e['ts']})"
        if e["name"] == "queue_wait":
            continue  # synthesized start; overlaps freely (see docstring)
        end = e["ts"] + e["dur"]
        while stack and stack[-1] <= e["ts"] + EPS_US:
            stack.pop()
        if stack and end > stack[-1] + EPS_US:
            bad |= err(path, f"{ctx}: span [{e['ts']}, {end}] straddles the "
                             f"enclosing span's end {stack[-1]} — begin/end "
                             f"pairing broken")
        stack.append(end)
    return bad


def check_doc(doc, path):
    bad = 0
    if not isinstance(doc, dict):
        return err(path, "top level is not an object")
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return err(path, "missing 'traceEvents' array")
    if not events:
        return err(path, "'traceEvents' is empty — a traced run records at "
                         "least one span")
    tracks = {}
    for i, e in enumerate(events):
        bad |= check_event(e, path, f"traceEvents[{i}]")
        if bad:
            return bad
        tracks.setdefault(e["tid"], []).append(e)
    for tid in sorted(tracks):
        bad |= check_track(tid, tracks[tid], path)
    return bad


# ---------------------------------------------------------------- self-test


def _event(name, ts, dur, tid=1, **over):
    e = {"name": name, "cat": "nmsparse", "ph": "X", "ts": ts, "dur": dur,
         "pid": 1, "tid": tid, "args": {"id": 7}}
    e.update(over)
    return e


def _good_doc():
    """Two tids; tid 1 has a tick_build enclosing two attention spans."""
    return {
        "displayTimeUnit": "ms",
        "traceEvents": [
            _event("tick_build", 10.0, 50.0),
            _event("attention", 12.0, 10.0),
            _event("attention", 30.0, 20.0),
            _event("reply", 70.0, 5.0),
            _event("queue_wait", 5.0, 100.0, tid=2),
            _event("lm_head", 200.0, 3.0, tid=2),
        ],
    }


def self_test():
    import contextlib
    import copy
    import io

    failures = []

    def expect_good(doc, label):
        if check_doc(copy.deepcopy(doc), f"<self-test:{label}>") != 0:
            failures.append(f"good fixture rejected: {label}")

    def expect_bad(label, mutate):
        doc = copy.deepcopy(_good_doc())
        mutate(doc)
        with contextlib.redirect_stderr(io.StringIO()):
            rejected = check_doc(doc, f"<self-test:{label}>") != 0
        if not rejected:
            failures.append(f"bad fixture accepted: {label}")

    expect_good(_good_doc(), "good trace")

    def straddle(doc):
        # Starts inside the tick_build [10, 60] but ends beyond it.
        doc["traceEvents"].insert(3, _event("attention", 55.0, 30.0))

    def non_monotone(doc):
        doc["traceEvents"][3]["ts"] = 1.0  # reply before the tick it follows

    expect_bad("empty traceEvents", lambda d: d.update(traceEvents=[]))
    expect_bad("missing traceEvents", lambda d: d.pop("traceEvents"))
    expect_bad("missing dur", lambda d: d["traceEvents"][0].pop("dur"))
    expect_bad("negative dur",
               lambda d: d["traceEvents"][0].update(dur=-1.0))
    expect_bad("non-complete ph",
               lambda d: d["traceEvents"][0].update(ph="B"))
    expect_bad("unknown phase name",
               lambda d: d["traceEvents"][0].update(name="warp_drive"))
    expect_bad("missing args.id",
               lambda d: d["traceEvents"][0].update(args={}))
    expect_bad("per-tid timestamps not monotone", non_monotone)
    expect_bad("child straddles parent end", straddle)
    # Disjoint same-tid spans (no nesting at all) are fine.
    flat = {"displayTimeUnit": "ms",
            "traceEvents": [_event("pack", 10.0 * i, 5.0) for i in range(4)]}
    expect_good(flat, "flat disjoint spans")
    # Exact shared boundaries (child ends where parent ends) are fine.
    snug = {"displayTimeUnit": "ms",
            "traceEvents": [_event("tick_build", 0.0, 10.0),
                            _event("attention", 4.0, 6.0)]}
    expect_good(snug, "child sharing the parent's end")
    # Coarse clock: parent and first child share a start timestamp, and
    # the child (recorded first at guard drop) even precedes the parent
    # in file order — the sweep's (ts, -dur) tie order must sort it out.
    tied = {"displayTimeUnit": "ms",
            "traceEvents": [_event("site_matmul_q", 0.0, 4.0),
                            _event("tick_build", 0.0, 10.0)]}
    expect_good(tied, "parent sharing its first child's start")
    # queue_wait spans overlap freely (synthesized starts): two waits
    # ending at almost the same dispatch straddle each other — fine.
    waits = {"displayTimeUnit": "ms",
             "traceEvents": [_event("queue_wait", 0.0, 50.0, tid=10_001),
                             _event("queue_wait", 20.0, 30.5, tid=10_001)]}
    expect_good(waits, "overlapping queue_wait spans")

    if failures:
        for f in failures:
            print(f"check_trace_json --self-test: FAIL: {f}", file=sys.stderr)
        return 1
    print("check_trace_json --self-test: all fixtures behaved")
    return 0


def main(argv):
    if argv[1:] == ["--self-test"]:
        return self_test()
    if not argv[1:]:
        print(__doc__.strip(), file=sys.stderr)
        return 2
    bad = 0
    for arg in argv[1:]:
        path = Path(arg)
        try:
            doc = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            bad |= err(path, f"unreadable: {e}")
            continue
        n = len(doc.get("traceEvents", [])) if isinstance(doc, dict) else 0
        if check_doc(doc, path):
            bad = 1
        else:
            print(f"check_trace_json: {path}: {n} event(s) OK")
    return bad


if __name__ == "__main__":
    sys.exit(main(sys.argv))
