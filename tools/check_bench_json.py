#!/usr/bin/env python3
"""Schema check for the machine-readable bench dumps (BENCH_*.json).

Three emitters write these files (see DESIGN.md §3):

- rust/benches/substrate.rs -> BENCH_sparsity.json, BENCH_packed.json
- rust/benches/tables.rs    -> BENCH_sparsify_overhead.json
- rust/src/launcher/loadgen.rs (`nmsparse loadgen`, also wrapped by
  rust/benches/serving.rs)  -> BENCH_serving.json; `--sweep` emits
  BENCH_serving_sweep.json
- rust/benches/decode.rs    -> BENCH_decode.json (native KV-cached decode
  engine: step cost vs context for the cached and full-context loops,
  batched step_batch vs sequential per-session tok/s per lane count,
  the threads x lanes worker-pool grid, measured packed-vs-dense
  activation bytes)

`nmsparse table table6`/`table serving` and `examples/hw_breakeven.rs`
consume them, so a malformed dump silently degrades the measured columns
back to the analytic fallbacks. This script fails CI loudly instead.
Files that have not been produced yet are fine (benches are optional in
the tier-1 gate); files that exist but violate their schema are not, and
a BENCH_*.json with no registered schema is an error (every emitter must
register here).

Usage: tools/check_bench_json.py [dir ...]   (default: repo root and rust/)
       tools/check_bench_json.py --self-test (run the checkers against
                                              inline good/bad fixtures)
"""

import json
import sys
from pathlib import Path


def err(path, msg):
    print(f"check_bench_json: {path}: {msg}", file=sys.stderr)
    return 1


def require(obj, key, types, path, ctx):
    if key not in obj:
        return err(path, f"{ctx}: missing required key '{key}'")
    if not isinstance(obj[key], types):
        return err(path, f"{ctx}: key '{key}' has type {type(obj[key]).__name__}")
    return 0


def check_patterns(doc, path, required, optional=()):
    """Common shape: {"patterns": {"<pattern>": {required...}}}, non-empty."""
    bad = require(doc, "patterns", dict, path, "top level")
    if bad:
        return bad
    if not doc["patterns"]:
        return err(path, "'patterns' is empty")
    for name, entry in doc["patterns"].items():
        if not isinstance(entry, dict):
            return err(path, f"pattern '{name}' is not an object")
        for key in required:
            bad |= require(entry, key, (int, float), path, f"pattern '{name}'")
        for key in optional:
            if key in entry and not isinstance(entry[key], (int, float)):
                bad |= err(path, f"pattern '{name}': optional key '{key}' not numeric")
    return bad


def check_sparsity(doc, path):
    return check_patterns(
        doc,
        path,
        required=(
            "seed_rows_per_sec",
            "fused_row_rows_per_sec",
            "fused_batch_rows_per_sec",
            "fused_row_speedup_vs_seed",
            "fused_batch_speedup_vs_seed",
        ),
    )


def check_overhead(doc, path):
    bad = check_patterns(doc, path, required=("overhead_frac",),
                         optional=("sparsify_s_per_forward",))
    for name, entry in doc.get("patterns", {}).items():
        frac = entry.get("overhead_frac")
        if isinstance(frac, (int, float)) and frac < 0:
            bad |= err(path, f"pattern '{name}': negative overhead_frac {frac}")
    return bad


def check_packed(doc, path):
    bad = check_patterns(
        doc,
        path,
        required=(
            "dense_bytes_per_row",
            "packed_bytes_per_row",
            "measured_bandwidth_reduction",
            "pack_gbps",
            "unpack_gbps",
            "packed_gemv_rows_per_sec",
            "dense_gemv_rows_per_sec",
            "packed_gemv_speedup",
        ),
        optional=(
            "pack_batch_gbps",
            "codec_word_blocks_per_sec",
            "codec_bit_blocks_per_sec",
            "codec_word_speedup",
        ),
    )
    if bad:
        return bad
    for name, entry in doc["patterns"].items():
        dense = entry["dense_bytes_per_row"]
        packed = entry["packed_bytes_per_row"]
        r = entry["measured_bandwidth_reduction"]
        if packed <= 0 or dense <= 0:
            bad |= err(path, f"pattern '{name}': non-positive bytes/row")
        elif abs(r - dense / packed) > 1e-6 * max(r, 1.0):
            bad |= err(
                path,
                f"pattern '{name}': measured_bandwidth_reduction {r} != "
                f"dense/packed {dense / packed}",
            )
    # The compressed stream must actually be smaller than dense somewhere.
    if not any(e["packed_bytes_per_row"] < e["dense_bytes_per_row"]
               for e in doc["patterns"].values()):
        bad |= err(path, "no pattern shows packed < dense bytes/row")
    return bad


def check_latency_block(obj, key, path, ctx):
    """A `{mean, p50, p95, p99, max}` millisecond block with monotone
    tail percentiles (`latency_ms`, `queue_wait_ms`)."""
    bad = require(obj, key, dict, path, ctx)
    if bad:
        return bad
    lat = obj[key]
    for k in ("mean", "p50", "p95", "p99", "max"):
        bad |= require(lat, k, (int, float), path, f"{ctx}.{key}")
    if bad:
        return bad
    if not lat["p50"] <= lat["p95"] <= lat["p99"]:
        bad |= err(path, f"{ctx}.{key}: percentiles not monotone: "
                         f"p50={lat['p50']} p95={lat['p95']} p99={lat['p99']}")
    return bad


KNOWN_PHASES = frozenset((
    "queue_wait", "tick_build", "prefill_block",
    "site_matmul_q", "site_matmul_k", "site_matmul_v", "site_matmul_o",
    "site_matmul_gate", "site_matmul_up", "site_matmul_down",
    "sparsify", "pack", "attention", "lm_head", "reply", "engine_build",
))

# On any one thread the leaf engine phases are disjoint in time, so their
# totals sum to at most wall x recording-threads (plus slack for clock
# jitter). Parent phases (tick_build, prefill_block) and the
# cross-request queue_wait overlap freely and stay out of the sum.
LEAF_PHASES = frozenset((
    "site_matmul_q", "site_matmul_k", "site_matmul_v", "site_matmul_o",
    "site_matmul_gate", "site_matmul_up", "site_matmul_down",
    "attention", "lm_head",
))


def check_phases(doc, path):
    """The util::trace `phases` block shared by BENCH_serving.json and
    BENCH_decode.json: wall clock, recorder bound, drop accounting and a
    per-phase `{count, total_ms, p50_ms, p95_ms}` breakdown."""
    bad = require(doc, "phases", dict, path, "top level")
    if bad:
        return bad
    ph = doc["phases"]
    for key in ("wall_ms", "recorders", "dropped_spans"):
        bad |= require(ph, key, (int, float), path, "phases")
    bad |= require(ph, "breakdown", dict, path, "phases")
    if bad:
        return bad
    if ph["wall_ms"] <= 0:
        bad |= err(path, f"phases: wall_ms {ph['wall_ms']} <= 0")
    if ph["recorders"] < 1:
        bad |= err(path, f"phases: recorders {ph['recorders']} < 1 — a traced "
                         f"run has at least one recording thread")
    if ph["dropped_spans"] < 0:
        bad |= err(path, f"phases: negative dropped_spans {ph['dropped_spans']}")
    if not ph["breakdown"]:
        return bad | err(path, "phases: empty breakdown — a traced run records "
                               "at least one phase")
    leaf_ms = 0.0
    for name, e in ph["breakdown"].items():
        ctx = f"phases.breakdown.{name}"
        if name not in KNOWN_PHASES:
            bad |= err(path, f"{ctx}: unknown phase name (span taxonomy: "
                             f"DESIGN.md §2.14)")
            continue
        if not isinstance(e, dict):
            bad |= err(path, f"{ctx} is not an object")
            continue
        for key in ("count", "total_ms", "p50_ms", "p95_ms"):
            bad |= require(e, key, (int, float), path, ctx)
        if bad:
            return bad
        if e["count"] < 1:
            bad |= err(path, f"{ctx}: count {e['count']} < 1 — empty phases "
                             f"are omitted, not zeroed")
        if e["total_ms"] < 0:
            bad |= err(path, f"{ctx}: negative total_ms {e['total_ms']}")
        if e["p50_ms"] > e["p95_ms"]:
            bad |= err(path, f"{ctx}: p50 {e['p50_ms']} > p95 {e['p95_ms']}")
        if name in LEAF_PHASES:
            leaf_ms += e["total_ms"]
    limit = ph["wall_ms"] * max(ph["recorders"], 1) * 1.05
    if ph["wall_ms"] > 0 and leaf_ms > limit:
        bad |= err(path, f"phases: leaf phase totals ({leaf_ms:.1f} ms) exceed "
                         f"wall x recorders ({limit:.1f} ms) — per-thread leaf "
                         f"spans are disjoint, so this breakdown is "
                         f"inconsistent")
    return bad


# Weighted-fair admission gate (DESIGN.md §2.15). Applies only when the
# run actually exercised fairness: >= 2 tenants, equal DRR dispatch
# weights, and a skewed traffic mix (heaviest tenant offered at least
# FAIRNESS_SKEW x the lightest). The light tenant's queue-wait p95 must
# then stay within FAIRNESS_RATIO x the heavy tenant's — a floor absorbs
# near-zero waits on unloaded runs, where the ratio is pure noise.
FAIRNESS_RATIO = 4.0
FAIRNESS_FLOOR_MS = 2.0
FAIRNESS_SKEW = 4.0


def check_fairness(ten, path, ctx):
    rows = ten["per_tenant"]
    if len(rows) < 2 or len(set(ten["weights"])) != 1:
        return 0  # unequal DRR weights skew dispatch on purpose
    subs = [t["submitted"] for t in rows]
    if min(subs) == 0:
        return 0  # a tenant with no traffic has no wait to compare
    heavy = max(rows, key=lambda t: t["submitted"])
    light = min(rows, key=lambda t: t["submitted"])
    if heavy["submitted"] < FAIRNESS_SKEW * light["submitted"]:
        return 0  # balanced traffic: nothing to shield
    light_p95 = light["queue_wait_ms"]["p95"]
    heavy_p95 = heavy["queue_wait_ms"]["p95"]
    limit = FAIRNESS_RATIO * max(heavy_p95, FAIRNESS_FLOOR_MS)
    if light_p95 > limit:
        return err(path, f"{ctx}: weighted-fair gate: light tenant "
                         f"{light['tenant']} queue-wait p95 {light_p95} ms "
                         f"exceeds {FAIRNESS_RATIO} x max(heavy p95 "
                         f"{heavy_p95} ms, {FAIRNESS_FLOOR_MS} ms floor) "
                         f"under equal DRR weights — DRR not shielding the "
                         f"light tenant")
    return 0


def check_tenants(entry, totals, path, ctx):
    """The `tenants` block (BENCH_serving.json, sweep points, and the
    serve stats op): DRR dispatch weights plus per-tenant counters and
    queue-wait/latency tails. `totals` maps per-tenant sum keys to the
    document totals they must reconcile with (None = total not emitted
    at this level, skip)."""
    bad = require(entry, "tenants", dict, path, ctx)
    if bad:
        return bad
    ten = entry["tenants"]
    tctx = f"{ctx}.tenants"
    bad |= require(ten, "count", (int, float), path, tctx)
    bad |= require(ten, "weights", list, path, tctx)
    bad |= require(ten, "per_tenant", list, path, tctx)
    if bad:
        return bad
    count = ten["count"]
    if count < 1:
        return err(path, f"{tctx}: count {count} < 1")
    if len(ten["per_tenant"]) != count:
        return err(path, f"{tctx}: count {count} != "
                         f"{len(ten['per_tenant'])} per_tenant entries")
    if len(ten["weights"]) != count or not all(
            isinstance(w, (int, float)) and w >= 1 for w in ten["weights"]):
        return err(path, f"{tctx}: 'weights' must hold {count} numeric "
                         f"entries >= 1 (DRR weights are clamped)")
    sums = {"submitted": 0, "served": 0, "shed": 0, "errors": 0}
    for i, t in enumerate(ten["per_tenant"]):
        ectx = f"{tctx}.per_tenant[{i}]"
        if not isinstance(t, dict):
            return err(path, f"{ectx} is not an object")
        for key in ("tenant", "submitted", "served", "shed", "errors"):
            bad |= require(t, key, (int, float), path, ectx)
        bad |= check_latency_block(t, "queue_wait_ms", path, ectx)
        bad |= check_latency_block(t, "latency_ms", path, ectx)
        if bad:
            return bad
        if t["tenant"] != i:
            bad |= err(path, f"{ectx}: tenant id {t['tenant']} != index {i}")
        for key in sums:
            if t[key] < 0:
                bad |= err(path, f"{ectx}: negative {key} {t[key]}")
            sums[key] += t[key]
        if t["served"] > t["submitted"]:
            bad |= err(path, f"{ectx}: served {t['served']} > "
                             f"submitted {t['submitted']}")
        if t["errors"] > t["served"]:
            bad |= err(path, f"{ectx}: errors {t['errors']} > "
                             f"served {t['served']}")
    # Per-tenant counters and document totals come from the same
    # ServerStats merge, so they must reconcile exactly.
    for key, total in totals.items():
        if total is not None and sums[key] != total:
            bad |= err(path, f"{tctx}: per-tenant {key} sums to {sums[key]} "
                             f"but the document total is {total}")
    bad |= check_fairness(ten, path, tctx)
    return bad


def check_wire_fields(entry, path, ctx, want_codec):
    """The wire-subsystem run fields: which codec the run roundtripped
    through, how many incremental chunk frames the clients observed, and
    the order-independent reply-transcript hash the codec-equivalence
    smoke compares across codecs."""
    bad = 0
    if want_codec:
        bad |= require(entry, "codec", str, path, ctx)
        if not bad and entry["codec"] not in ("direct", "json", "binary"):
            bad |= err(path, f"{ctx}: unknown codec '{entry['codec']}' "
                             f"(direct, json, binary)")
    bad |= require(entry, "stream_chunks", (int, float), path, ctx)
    if not bad and entry["stream_chunks"] < 0:
        bad |= err(path, f"{ctx}: negative stream_chunks "
                         f"{entry['stream_chunks']}")
    bad |= require(entry, "transcript_hash", str, path, ctx)
    if bad:
        return bad
    h = entry["transcript_hash"]
    if len(h) != 16 or any(c not in "0123456789abcdef" for c in h):
        bad |= err(path, f"{ctx}: transcript_hash '{h}' is not 16 lowercase "
                         f"hex digits")
    return bad


def check_serving(doc, path):
    bad = 0
    for key in ("mode", "backend"):
        bad |= require(doc, key, str, path, "top level")
    for key in ("replicas", "queue_cap", "requests", "served", "rejected",
                "errors", "wall_s", "throughput_rps", "batch_occupancy",
                "rejection_rate", "restarts", "retried", "timed_out",
                "failed", "timeout_rate", "failure_rate"):
        bad |= require(doc, key, (int, float), path, "top level")
    bad |= require(doc, "latency_ms", dict, path, "top level")
    if bad:
        return bad
    lat = doc["latency_ms"]
    for key in ("mean", "p50", "p95", "p99", "max"):
        bad |= require(lat, key, (int, float), path, "latency_ms")
    if bad:
        return bad
    if not lat["p50"] <= lat["p95"] <= lat["p99"]:
        bad |= err(path, f"latency percentiles not monotone: "
                         f"p50={lat['p50']} p95={lat['p95']} p99={lat['p99']}")
    if doc["served"] > 0 and doc["throughput_rps"] <= 0:
        bad |= err(path, "served > 0 but throughput_rps <= 0")
    if doc["served"] + doc["rejected"] > doc["requests"]:
        bad |= err(path, f"served + rejected ({doc['served']} + {doc['rejected']}) "
                         f"exceeds requests ({doc['requests']})")
    if not 0.0 <= doc["batch_occupancy"] <= 1.0 + 1e-9:
        bad |= err(path, f"batch_occupancy {doc['batch_occupancy']} outside [0, 1]")
    for key in ("rejection_rate", "timeout_rate", "failure_rate"):
        if not 0.0 <= doc[key] <= 1.0 + 1e-9:
            bad |= err(path, f"{key} {doc[key]} outside [0, 1]")
    # The error taxonomy nests: every timed-out or failed request is also
    # counted in `errors` (exactly-once accounting, DESIGN.md §2.12).
    if doc["timed_out"] + doc["failed"] > doc["errors"]:
        bad |= err(path, f"timed_out + failed ({doc['timed_out']} + {doc['failed']}) "
                         f"exceeds errors ({doc['errors']})")
    for key in ("restarts", "retried", "timed_out", "failed"):
        if doc[key] < 0:
            bad |= err(path, f"{key} {doc[key]} < 0")
    if doc["replicas"] < 1:
        bad |= err(path, f"replicas {doc['replicas']} < 1")
    # Server-side admission -> dispatch wait and the per-phase breakdown:
    # loadgen always records at metrics level, so both blocks are required.
    bad |= check_latency_block(doc, "queue_wait_ms", path, "top level")
    bad |= check_phases(doc, path)
    bad |= check_wire_fields(doc, path, "top level", want_codec=True)
    bad |= check_tenants(
        doc,
        {"served": doc["served"], "shed": doc["rejected"],
         "errors": doc["errors"], "submitted": None},
        path, "top level",
    )
    if doc["mode"] == "longmix":
        bad |= check_classes(doc, path, "top level")
    return bad


def check_classes(entry, path, ctx):
    """The longmix per-class block: `classes.{long_prompt,short_decode}`,
    each a `{count, latency_ms}` with monotone tail percentiles. Emitted
    (and therefore required) only for mode == "longmix" runs."""
    bad = require(entry, "classes", dict, path, ctx)
    if bad:
        return bad
    classes = entry["classes"]
    for name in ("long_prompt", "short_decode"):
        cctx = f"{ctx}.classes.{name}"
        bad |= require(classes, name, dict, path, f"{ctx}.classes")
        if bad:
            return bad
        c = classes[name]
        bad |= require(c, "count", (int, float), path, cctx)
        bad |= require(c, "latency_ms", dict, path, cctx)
        if bad:
            return bad
        if c["count"] <= 0:
            bad |= err(path, f"{cctx}: count {c['count']} <= 0 — a longmix run "
                             f"always completes requests of both classes")
        lat = c["latency_ms"]
        for key in ("mean", "p50", "p95", "p99", "max"):
            bad |= require(lat, key, (int, float), path, f"{cctx}.latency_ms")
        if bad:
            return bad
        if not lat["p50"] <= lat["p95"] <= lat["p99"]:
            bad |= err(path, f"{cctx}: latency percentiles not monotone: "
                             f"p50={lat['p50']} p95={lat['p95']} p99={lat['p99']}")
        if c["count"] > 0 and lat["p99"] <= 0:
            bad |= err(path, f"{cctx}: count > 0 but p99 <= 0")
    return bad


def check_serving_sweep(doc, path):
    bad = 0
    for key in ("mode", "backend"):
        bad |= require(doc, key, str, path, "top level")
    for key in ("replicas", "queue_cap", "requests_per_point"):
        bad |= require(doc, key, (int, float), path, "top level")
    bad |= require(doc, "points", list, path, "top level")
    if bad:
        return bad
    if not doc["points"]:
        return err(path, "'points' is empty — a sweep needs at least one rate")
    prev_rate = 0.0
    for i, p in enumerate(doc["points"]):
        ctx = f"points[{i}]"
        if not isinstance(p, dict):
            return err(path, f"{ctx} is not an object")
        for key in ("rate_rps", "served", "rejected", "throughput_rps",
                    "rejection_rate", "batch_occupancy", "timed_out",
                    "failed", "timeout_rate", "failure_rate"):
            bad |= require(p, key, (int, float), path, ctx)
        bad |= require(p, "latency_ms", dict, path, ctx)
        if bad:
            return bad
        for key in ("mean", "p50", "p95", "p99", "max"):
            bad |= require(p["latency_ms"], key, (int, float), path, f"{ctx}.latency_ms")
        if bad:
            return bad
        lat = p["latency_ms"]
        if not lat["p50"] <= lat["p95"] <= lat["p99"]:
            bad |= err(path, f"{ctx}: latency percentiles not monotone")
        bad |= check_latency_block(p, "queue_wait_ms", path, ctx)
        if p["rate_rps"] <= prev_rate:
            bad |= err(path, f"{ctx}: rates must be strictly increasing "
                             f"({p['rate_rps']} after {prev_rate})")
        prev_rate = p["rate_rps"]
        for key in ("rejection_rate", "timeout_rate", "failure_rate"):
            if not 0.0 <= p[key] <= 1.0 + 1e-9:
                bad |= err(path, f"{ctx}: {key} {p[key]} outside [0, 1]")
        if p["served"] + p["rejected"] > doc["requests_per_point"]:
            bad |= err(path, f"{ctx}: served + rejected exceeds requests_per_point")
        # Each point is a full loadgen run, so it carries the wire-run
        # fields and the per-tenant block (codec is run-wide, not
        # per-point, and a point emits no `errors` total to reconcile).
        bad |= check_wire_fields(p, path, ctx, want_codec=False)
        bad |= check_tenants(
            p,
            {"served": p["served"], "shed": p["rejected"],
             "errors": None, "submitted": None},
            path, ctx,
        )
        # Longmix sweeps exist to expose the per-class tail; a point
        # without the class split silently loses the measurement.
        if doc["mode"] == "longmix":
            bad |= check_classes(p, path, ctx)
    return bad


def check_decode(doc, path):
    bad = 0
    for key in ("backend", "pattern", "method"):
        bad |= require(doc, key, str, path, "top level")
    for key in ("prefill_tokens_per_sec", "decode_tokens_per_sec",
                "cached_step_growth", "full_step_growth",
                "dense_bytes_per_step", "packed_bytes_per_step",
                "bytes_reduction"):
        bad |= require(doc, key, (int, float), path, "top level")
    bad |= require(doc, "model", dict, path, "top level")
    bad |= require(doc, "contexts", list, path, "top level")
    bad |= require(doc, "batched", list, path, "top level")
    if bad:
        return bad
    for key in ("vocab", "d_model", "n_layers", "ffn", "max_seq"):
        bad |= require(doc["model"], key, (int, float), path, "model")
    if not doc["contexts"]:
        return err(path, "'contexts' is empty")
    prev_ctx = 0
    for i, c in enumerate(doc["contexts"]):
        ctx = f"contexts[{i}]"
        if not isinstance(c, dict):
            return err(path, f"{ctx} is not an object")
        for key in ("context", "cached_step_ms", "full_step_ms"):
            bad |= require(c, key, (int, float), path, ctx)
        if bad:
            return bad
        if c["context"] <= prev_ctx:
            bad |= err(path, f"{ctx}: contexts must be strictly increasing")
        prev_ctx = c["context"]
        if c["cached_step_ms"] <= 0 or c["full_step_ms"] <= 0:
            bad |= err(path, f"{ctx}: non-positive step time")
    # The point of the KV cache: the cached step must not inherit the
    # full-context baseline's growth with context length.
    if doc["full_step_growth"] <= doc["cached_step_growth"]:
        bad |= err(path, f"cached step cost grew as fast as the full-context "
                         f"baseline (cached {doc['cached_step_growth']}x vs "
                         f"full {doc['full_step_growth']}x) — KV cache not "
                         f"paying off")
    if doc["prefill_tokens_per_sec"] <= 0 or doc["decode_tokens_per_sec"] <= 0:
        bad |= err(path, "non-positive tokens/sec")
    # Blocked prefill grid: prompt ingestion tok/s vs block size, block 0
    # (or 1) being the per-token baseline. The bench pins every blocked
    # variant bitwise logits-identical to the baseline before timing, so
    # the gate here is pure performance: at a prefill prompt long enough
    # to amortize (>= 64 positions), the best blocked variant must not
    # ingest slower than per-token — otherwise blocked prefill is dead
    # weight and the dump should fail loudly.
    bad |= require(doc, "prefill_prompt_tokens", (int, float), path, "top level")
    bad |= require(doc, "prefill_block_grid", list, path, "top level")
    if bad:
        return bad
    if not doc["prefill_block_grid"]:
        return err(path, "'prefill_block_grid' is empty — the bench always "
                         "emits the prefill grid")
    prev_block = -1
    baseline_tps = None
    blocked_tps = []
    for i, r in enumerate(doc["prefill_block_grid"]):
        ctx = f"prefill_block_grid[{i}]"
        if not isinstance(r, dict):
            return err(path, f"{ctx} is not an object")
        for key in ("block", "tokens_per_sec"):
            bad |= require(r, key, (int, float), path, ctx)
        if bad:
            return bad
        if r["block"] <= prev_block:
            bad |= err(path, f"{ctx}: block sizes must be strictly increasing")
        prev_block = r["block"]
        if r["tokens_per_sec"] <= 0:
            bad |= err(path, f"{ctx}: non-positive tokens/sec")
        if r["block"] <= 1:
            baseline_tps = r["tokens_per_sec"]
        else:
            blocked_tps.append(r["tokens_per_sec"])
    if baseline_tps is None:
        bad |= err(path, "prefill_block_grid: no per-token baseline row "
                         "(block <= 1) — the blocked/per-token comparison "
                         "never ran")
    if len(blocked_tps) < 2:
        bad |= err(path, f"prefill_block_grid: only {len(blocked_tps)} blocked "
                         f"row(s) (block > 1) — the grid is vacuous")
    if baseline_tps is not None and blocked_tps and \
            doc["prefill_prompt_tokens"] >= 64 and \
            max(blocked_tps) < baseline_tps:
        bad |= err(path, f"prefill_block_grid: best blocked prefill "
                         f"({max(blocked_tps)} tok/s) slower than per-token "
                         f"({baseline_tps} tok/s) at prompt "
                         f"{doc['prefill_prompt_tokens']} — blocked prefill "
                         f"not paying")
    # Batched session stepping: one StepBatch across K lanes vs K
    # sequential per-session steps. Batch sizes strictly increase, and
    # batching must actually pay at batch >= 4 (the amortization the
    # batched API exists for).
    if not doc["batched"]:
        return err(path, "'batched' is empty — the bench always emits lane rows")
    prev_batch = 0
    for i, b in enumerate(doc["batched"]):
        ctx = f"batched[{i}]"
        if not isinstance(b, dict):
            return err(path, f"{ctx} is not an object")
        for key in ("batch", "batched_tokens_per_sec", "sequential_tokens_per_sec"):
            bad |= require(b, key, (int, float), path, ctx)
        if bad:
            return bad
        if b["batch"] <= prev_batch:
            bad |= err(path, f"{ctx}: batch sizes must be strictly increasing")
        prev_batch = b["batch"]
        if b["batched_tokens_per_sec"] <= 0 or b["sequential_tokens_per_sec"] <= 0:
            bad |= err(path, f"{ctx}: non-positive tokens/sec")
        elif b["batch"] >= 4 and \
                b["batched_tokens_per_sec"] < b["sequential_tokens_per_sec"]:
            bad |= err(path, f"{ctx}: batched decode ({b['batched_tokens_per_sec']}"
                             f" tok/s) slower than sequential"
                             f" ({b['sequential_tokens_per_sec']} tok/s) at batch"
                             f" {b['batch']} — step_batch not amortizing")
    # A sparse pattern must actually shrink the moved activation bytes.
    if doc["pattern"] != "dense" and \
            doc["packed_bytes_per_step"] >= doc["dense_bytes_per_step"]:
        bad |= err(path, f"packed bytes/step {doc['packed_bytes_per_step']} not "
                         f"below dense {doc['dense_bytes_per_step']}")
    # Threads x lanes worker-pool grid: the bench pins every cell bitwise
    # logits-identical to the single-threaded run before timing, so the
    # only thing left to gate here is that threading actually pays where
    # there are rows to spread.
    bad |= require(doc, "thread_grid", list, path, "top level")
    if bad:
        return bad
    if not doc["thread_grid"]:
        return err(path, "'thread_grid' is empty — the bench always emits the grid")
    cells = {}
    for i, g in enumerate(doc["thread_grid"]):
        ctx = f"thread_grid[{i}]"
        if not isinstance(g, dict):
            return err(path, f"{ctx} is not an object")
        for key in ("threads", "lanes", "tokens_per_sec"):
            bad |= require(g, key, (int, float), path, ctx)
        if bad:
            return bad
        if g["threads"] < 1 or g["lanes"] < 1:
            bad |= err(path, f"{ctx}: threads/lanes must be >= 1")
        if g["tokens_per_sec"] <= 0:
            bad |= err(path, f"{ctx}: non-positive tokens/sec")
        cell = (g["threads"], g["lanes"])
        if cell in cells:
            bad |= err(path, f"{ctx}: duplicate (threads, lanes) cell {cell}")
        cells[cell] = g["tokens_per_sec"]
    # The monotone gate: with lane-level work to spread (lanes >= 4), a
    # 4-wide pool must not decode slower than the single-threaded run.
    gated = 0
    for (threads, lanes), tps in sorted(cells.items()):
        if threads == 4 and lanes >= 4 and (1, lanes) in cells:
            gated += 1
            if tps < cells[(1, lanes)]:
                bad |= err(path, f"thread_grid: 4 threads ({tps} tok/s) slower "
                                 f"than 1 thread ({cells[(1, lanes)]} tok/s) at "
                                 f"lanes {lanes} — worker pool not paying")
    if gated == 0:
        bad |= err(path, "thread_grid: no (threads=4, lanes>=4) cell with a "
                         "threads=1 twin — the monotone gate never ran")
    # The traced pass always runs (separate from the timed closures), so
    # the per-phase breakdown is required in every complete dump.
    bad |= check_phases(doc, path)
    return bad


CHECKERS = {
    "BENCH_sparsity.json": check_sparsity,
    "BENCH_sparsify_overhead.json": check_overhead,
    "BENCH_packed.json": check_packed,
    "BENCH_serving.json": check_serving,
    "BENCH_serving_sweep.json": check_serving_sweep,
    "BENCH_decode.json": check_decode,
}


def _good_decode_doc():
    """A minimal BENCH_decode.json that every decode gate accepts."""
    contexts = [{"context": c, "cached_step_ms": 0.10 + 0.01 * i,
                 "full_step_ms": 0.2 * (i + 1)}
                for i, c in enumerate((8, 32, 96))]
    batched = [{"batch": b,
                "batched_tokens_per_sec": 1000.0 * max(b, 2),
                "sequential_tokens_per_sec": 900.0 * b}
               for b in (1, 4, 8)]
    grid = [{"threads": t, "lanes": l,
             "tokens_per_sec": 800.0 * (t if l >= 4 else 1.0) * l}
            for l in (1, 4, 16) for t in (1, 2, 4)]
    prefill_grid = [{"block": b, "tokens_per_sec": 4.0e4 * max(b, 1)}
                    for b in (0, 4, 16, 64)]
    return {
        "suite": "decode", "backend": "synthetic",
        "pattern": "8:16", "method": "ACT",
        "model": {"vocab": 160, "d_model": 128, "n_layers": 2,
                  "ffn": 256, "max_seq": 128},
        "prefill_tokens_per_sec": 5.0e4, "decode_tokens_per_sec": 2.0e4,
        "prefill_prompt_tokens": 64, "prefill_block_grid": prefill_grid,
        "contexts": contexts, "batched": batched, "thread_grid": grid,
        "cached_step_growth": 1.2, "full_step_growth": 3.0,
        "dense_bytes_per_step": 1000.0, "packed_bytes_per_step": 400.0,
        "bytes_reduction": 2.5, "phases": _good_phases(),
    }


def _good_phases():
    """A valid util::trace `phases` block (leaf sum within the bound)."""
    def entry(count, total_ms):
        per = total_ms / count
        return {"count": count, "total_ms": total_ms,
                "p50_ms": per, "p95_ms": 2.0 * per}
    return {
        "wall_ms": 500.0, "recorders": 3, "dropped_spans": 0,
        "breakdown": {
            "queue_wait": entry(100, 50.0),
            "tick_build": entry(40, 20.0),
            "site_matmul_q": entry(64, 80.0),
            "attention": entry(64, 120.0),
            "lm_head": entry(64, 60.0),
            "reply": entry(98, 5.0),
        },
    }


def _good_queue_wait():
    """A valid `queue_wait_ms` block (monotone tail)."""
    return {"mean": 0.5, "p50": 0.4, "p95": 1.0, "p99": 1.5, "max": 2.0}


def _good_classes():
    """A valid longmix `classes` block (both classes, monotone tails)."""
    return {
        name: {"count": n,
               "latency_ms": {"mean": 2.0, "p50": 1.5, "p95": 4.0,
                              "p99": 6.0, "max": 8.0}}
        for name, n in (("long_prompt", 5), ("short_decode", 15))
    }


def _good_tenants(served, shed, errors):
    """A valid 2-tenant `tenants` block on a 10:1 traffic skew at equal
    DRR weights, with the document totals split so the reconciliation
    sums hold. The light tenant's queue-wait p95 sits below the heavy
    tenant's, as DRR dispatch produces."""
    def tail(p95):
        return {"mean": p95 / 2.0, "p50": p95 / 2.0, "p95": p95,
                "p99": p95 * 1.5, "max": p95 * 2.0}
    light_served = max(served // 11, 1)
    heavy_served = served - light_served
    return {
        "count": 2,
        "weights": [1, 1],
        "per_tenant": [
            {"tenant": 0, "submitted": heavy_served + shed,
             "served": heavy_served, "shed": shed, "errors": errors,
             "queue_wait_ms": tail(8.0), "latency_ms": tail(12.0)},
            {"tenant": 1, "submitted": light_served,
             "served": light_served, "shed": 0, "errors": 0,
             "queue_wait_ms": tail(1.0), "latency_ms": tail(3.0)},
        ],
    }


def _good_sweep_doc():
    """A minimal longmix BENCH_serving_sweep.json every sweep gate accepts."""
    points = []
    for rate in (200.0, 400.0):
        points.append({
            "rate_rps": rate, "served": 20, "rejected": 0,
            "throughput_rps": rate * 0.9,
            "latency_ms": {"mean": 1.0, "p50": 0.8, "p95": 2.0, "p99": 3.0,
                           "max": 4.0},
            "rejection_rate": 0.0, "batch_occupancy": 0.5,
            "timed_out": 0, "failed": 0, "timeout_rate": 0.0,
            "failure_rate": 0.0, "restarts": 0, "retried": 0,
            "queue_wait_ms": _good_queue_wait(),
            "stream_chunks": 0, "transcript_hash": "00ff00ff00ff00ff",
            "tenants": _good_tenants(served=20, shed=0, errors=0),
            "classes": _good_classes(),
        })
    return {
        "suite": "serving_sweep", "mode": "longmix", "backend": "native",
        "replicas": 2, "queue_cap": 64, "requests_per_point": 20,
        "points": points,
    }


def _good_serving_doc():
    """A minimal BENCH_serving.json that every serving gate accepts."""
    return {
        "suite": "serving", "mode": "mixed", "backend": "synthetic",
        "replicas": 2, "queue_cap": 64, "requests": 100,
        "served": 98, "rejected": 2, "errors": 5, "wall_s": 0.5,
        "throughput_rps": 196.0,
        "latency_ms": {"mean": 1.0, "p50": 0.8, "p95": 2.0, "p99": 3.0,
                       "max": 4.0},
        "batch_occupancy": 0.7, "rejection_rate": 0.02, "stolen": 1,
        "restarts": 2, "retried": 1, "timed_out": 2, "failed": 3,
        "timeout_rate": 0.02, "failure_rate": 0.03,
        "queue_wait_ms": _good_queue_wait(), "phases": _good_phases(),
        "codec": "direct", "stream_chunks": 0,
        "transcript_hash": "0123456789abcdef",
        "tenants": _good_tenants(served=98, shed=2, errors=5),
    }


def self_test():
    """Run check_decode and check_serving against inline good/bad fixtures.

    The gates only fire on files that exist, so a regression that silently
    stops rejecting a bad dump would otherwise go unnoticed until a bench
    actually produced one. CI runs this mode unconditionally.
    """
    import contextlib
    import copy
    import io

    failures = []

    def expect_good(checker, doc, label):
        if checker(copy.deepcopy(doc), f"<self-test:{label}>") != 0:
            failures.append(f"good fixture rejected: {label}")

    def make_expect_bad(checker, good):
        def expect_bad(label, mutate):
            doc = copy.deepcopy(good)
            mutate(doc)
            with contextlib.redirect_stderr(io.StringIO()):
                rejected = checker(doc, f"<self-test:{label}>") != 0
            if not rejected:
                failures.append(f"bad fixture accepted: {label}")
        return expect_bad

    good = _good_decode_doc()
    expect_good(check_decode, good, "good decode")
    expect_bad = make_expect_bad(check_decode, good)

    def slow_t4(doc):
        for g in doc["thread_grid"]:
            if g["threads"] == 4 and g["lanes"] == 4:
                g["tokens_per_sec"] = 1.0  # below the threads=1 twin

    def vacuous_grid(doc):
        doc["thread_grid"] = [g for g in doc["thread_grid"] if g["lanes"] == 1]

    def duplicate_cell(doc):
        doc["thread_grid"].append(dict(doc["thread_grid"][0]))

    expect_bad("missing thread_grid", lambda d: d.pop("thread_grid"))
    expect_bad("empty thread_grid", lambda d: d.update(thread_grid=[]))
    expect_bad("thread gate violated", slow_t4)
    expect_bad("vacuous grid (no lanes>=4 pair)", vacuous_grid)
    expect_bad("duplicate grid cell", duplicate_cell)
    expect_bad("non-positive grid tok/s",
               lambda d: d["thread_grid"][0].update(tokens_per_sec=0.0))
    expect_bad("batched slower at batch 4",
               lambda d: d["batched"][1].update(batched_tokens_per_sec=1.0))
    expect_bad("cached growth not below full growth",
               lambda d: d.update(cached_step_growth=5.0))
    expect_bad("packed bytes not below dense",
               lambda d: d.update(packed_bytes_per_step=2000.0))
    expect_bad("decode missing phases", lambda d: d.pop("phases"))

    # ---- prefill_block_grid gates ----
    def slow_blocked(doc):
        for r in doc["prefill_block_grid"]:
            if r["block"] > 1:
                r["tokens_per_sec"] = 1.0  # every blocked row below baseline

    def vacuous_prefill(doc):
        doc["prefill_block_grid"] = doc["prefill_block_grid"][:2]

    def no_baseline(doc):
        doc["prefill_block_grid"] = \
            [r for r in doc["prefill_block_grid"] if r["block"] > 1]

    def short_prompt_slow_blocked(doc):
        slow_blocked(doc)
        doc["prefill_prompt_tokens"] = 16  # below the 64-position gate

    expect_bad("missing prefill_block_grid",
               lambda d: d.pop("prefill_block_grid"))
    expect_bad("missing prefill_prompt_tokens",
               lambda d: d.pop("prefill_prompt_tokens"))
    expect_bad("empty prefill_block_grid",
               lambda d: d.update(prefill_block_grid=[]))
    expect_bad("prefill blocks not increasing",
               lambda d: d["prefill_block_grid"].__setitem__(
                   1, dict(d["prefill_block_grid"][3])))
    expect_bad("non-positive prefill tok/s",
               lambda d: d["prefill_block_grid"][0].update(tokens_per_sec=0.0))
    expect_bad("blocked prefill slower than per-token at prompt 64",
               slow_blocked)
    expect_bad("vacuous prefill grid (one blocked row)", vacuous_prefill)
    expect_bad("no per-token baseline row", no_baseline)
    # The perf gate is scoped: below 64 prompt positions a slow blocked
    # path is tolerated (nothing to amortize), the schema still holds.
    short = copy.deepcopy(good)
    short_prompt_slow_blocked(short)
    expect_good(check_decode, short, "short-prompt slow blocked tolerated")

    serving = _good_serving_doc()
    expect_good(check_serving, serving, "good serving")
    expect_bad = make_expect_bad(check_serving, serving)
    expect_bad("missing restarts", lambda d: d.pop("restarts"))
    expect_bad("missing timeout_rate", lambda d: d.pop("timeout_rate"))
    expect_bad("timeout_rate above 1", lambda d: d.update(timeout_rate=1.5))
    expect_bad("negative failure_rate", lambda d: d.update(failure_rate=-0.1))
    expect_bad("timed_out + failed exceed errors",
               lambda d: d.update(timed_out=4, failed=4))
    expect_bad("negative retried", lambda d: d.update(retried=-1))
    expect_bad("served + rejected exceed requests",
               lambda d: d.update(served=200))

    # ---- queue_wait_ms + phases gates ----
    def leaf_sum_overflow(doc):
        # wall 500ms x 3 recorders x 1.05 = 1575ms; push one leaf past it.
        doc["phases"]["breakdown"]["attention"]["total_ms"] = 5000.0

    def p50_above_p95(doc):
        e = doc["phases"]["breakdown"]["queue_wait"]
        e["p50_ms"] = 2.0 * e["p95_ms"]

    expect_bad("missing queue_wait_ms", lambda d: d.pop("queue_wait_ms"))
    expect_bad("queue_wait percentiles not monotone",
               lambda d: d["queue_wait_ms"].update(p95=5.0, p99=1.0))
    expect_bad("serving missing phases", lambda d: d.pop("phases"))
    expect_bad("phases missing wall_ms",
               lambda d: d["phases"].pop("wall_ms"))
    expect_bad("phases empty breakdown",
               lambda d: d["phases"].update(breakdown={}))
    expect_bad("phases zero recorders",
               lambda d: d["phases"].update(recorders=0))
    expect_bad("phases negative dropped_spans",
               lambda d: d["phases"].update(dropped_spans=-1))
    expect_bad("unknown phase name",
               lambda d: d["phases"]["breakdown"].update(
                   warp_drive={"count": 1, "total_ms": 1.0,
                               "p50_ms": 1.0, "p95_ms": 1.0}))
    expect_bad("phase entry with zero count",
               lambda d: d["phases"]["breakdown"]["reply"].update(count=0))
    expect_bad("phase entry missing p95_ms",
               lambda d: d["phases"]["breakdown"]["reply"].pop("p95_ms"))
    expect_bad("phase p50 above p95", p50_above_p95)
    expect_bad("leaf phase totals exceed wall x recorders", leaf_sum_overflow)

    # ---- wire fields + tenants + fairness gates ----
    def starved_light_tenant(doc):
        # Equal weights, 10:1 skew, light tenant's queue-wait p95 far
        # beyond the heavy tenant's — the DRR fairness gate must fire.
        doc["tenants"]["per_tenant"][1]["queue_wait_ms"] = \
            {"mean": 50.0, "p50": 40.0, "p95": 100.0, "p99": 150.0,
             "max": 200.0}

    def starved_but_weighted(doc):
        # The same starvation is accepted when the dispatch weights are
        # unequal — the operator asked for the skew, the gate is scoped
        # to equal-weight runs.
        starved_light_tenant(doc)
        doc["tenants"]["weights"] = [10, 1]

    expect_bad("missing codec", lambda d: d.pop("codec"))
    expect_bad("unknown codec name", lambda d: d.update(codec="carrier-pigeon"))
    expect_bad("negative stream_chunks", lambda d: d.update(stream_chunks=-1))
    expect_bad("missing transcript_hash", lambda d: d.pop("transcript_hash"))
    expect_bad("malformed transcript_hash",
               lambda d: d.update(transcript_hash="0xBEEF"))
    expect_bad("missing tenants block", lambda d: d.pop("tenants"))
    expect_bad("tenants count != per_tenant entries",
               lambda d: d["tenants"].update(count=3))
    expect_bad("tenants weights length mismatch",
               lambda d: d["tenants"].update(weights=[1]))
    expect_bad("tenants weight below 1",
               lambda d: d["tenants"].update(weights=[1, 0]))
    expect_bad("tenant id out of order",
               lambda d: d["tenants"]["per_tenant"][1].update(tenant=5))
    expect_bad("tenant served above submitted",
               lambda d: d["tenants"]["per_tenant"][1].update(served=10**6))
    expect_bad("tenant errors above served",
               lambda d: d["tenants"]["per_tenant"][1].update(errors=10**6))
    expect_bad("tenant missing queue_wait_ms",
               lambda d: d["tenants"]["per_tenant"][0].pop("queue_wait_ms"))
    expect_bad("per-tenant served does not sum to document served",
               lambda d: d["tenants"]["per_tenant"][0].update(served=1))
    expect_bad("per-tenant shed does not sum to rejected",
               lambda d: d["tenants"]["per_tenant"][0].update(shed=7))
    expect_bad("fairness gate: light tenant starved at equal weights",
               starved_light_tenant)
    weighted = copy.deepcopy(serving)
    starved_but_weighted(weighted)
    expect_good(check_serving, weighted,
                "starved light tenant tolerated under unequal weights")
    # A single-tenant run has no fairness to gate; the block still
    # reconciles.
    single = copy.deepcopy(serving)
    single["tenants"] = {
        "count": 1, "weights": [1],
        "per_tenant": [{
            "tenant": 0, "submitted": 100, "served": 98, "shed": 2,
            "errors": 5, "queue_wait_ms": _good_queue_wait(),
            "latency_ms": {"mean": 1.0, "p50": 0.8, "p95": 2.0,
                           "p99": 3.0, "max": 4.0},
        }],
    }
    expect_good(check_serving, single, "single-tenant serving block")
    # Parent/overlapping phases stay out of the leaf sum: a huge
    # queue_wait total (many requests waiting concurrently) is fine.
    overlap = copy.deepcopy(serving)
    overlap["phases"]["breakdown"]["queue_wait"]["total_ms"] = 50_000.0
    expect_good(check_serving, overlap, "overlapping queue_wait beyond wall")
    # A longmix serving report must carry the per-class split.
    longmix_serving = copy.deepcopy(serving)
    longmix_serving["mode"] = "longmix"
    longmix_serving["classes"] = _good_classes()
    expect_good(check_serving, longmix_serving, "good longmix serving")
    expect_bad = make_expect_bad(check_serving, longmix_serving)
    expect_bad("longmix serving without classes",
               lambda d: d.pop("classes"))
    expect_bad("longmix class with zero count",
               lambda d: d["classes"]["long_prompt"].update(count=0))

    sweep = _good_sweep_doc()
    expect_good(check_serving_sweep, sweep, "good longmix sweep")
    expect_bad = make_expect_bad(check_serving_sweep, sweep)

    def class_tail_not_monotone(doc):
        lat = doc["points"][0]["classes"]["short_decode"]["latency_ms"]
        lat["p99"] = lat["p50"] / 2.0

    expect_bad("longmix point without classes",
               lambda d: d["points"][0].pop("classes"))
    expect_bad("missing short_decode class",
               lambda d: d["points"][1]["classes"].pop("short_decode"))
    expect_bad("class tail percentiles not monotone", class_tail_not_monotone)
    expect_bad("class missing p99",
               lambda d: d["points"][0]["classes"]["long_prompt"]
               ["latency_ms"].pop("p99"))
    expect_bad("sweep rates not increasing",
               lambda d: d["points"][1].update(rate_rps=100.0))
    expect_bad("sweep point missing queue_wait_ms",
               lambda d: d["points"][0].pop("queue_wait_ms"))
    expect_bad("sweep point missing tenants",
               lambda d: d["points"][0].pop("tenants"))
    expect_bad("sweep point missing transcript_hash",
               lambda d: d["points"][1].pop("transcript_hash"))
    expect_bad("sweep point tenant served not reconciling",
               lambda d: d["points"][0]["tenants"]["per_tenant"][0]
               .update(served=1, submitted=1))
    expect_bad("sweep point fairness violated",
               lambda d: d["points"][1]["tenants"]["per_tenant"][1]
               .update(queue_wait_ms={"mean": 50.0, "p50": 40.0,
                                      "p95": 100.0, "p99": 150.0,
                                      "max": 200.0}))
    # Non-longmix sweeps keep the old schema: no classes required.
    plain_sweep = copy.deepcopy(sweep)
    plain_sweep["mode"] = "mixed"
    for p in plain_sweep["points"]:
        p.pop("classes")
    expect_good(check_serving_sweep, plain_sweep, "plain sweep without classes")

    if failures:
        for f in failures:
            print(f"check_bench_json --self-test: FAIL: {f}", file=sys.stderr)
        return 1
    print("check_bench_json --self-test: all fixtures behaved")
    return 0


def main(argv):
    if argv[1:] == ["--self-test"]:
        return self_test()
    roots = [Path(p) for p in argv[1:]] or [Path("."), Path("rust")]
    seen, bad = 0, 0
    visited = set()
    for root in roots:
        if not root.is_dir():
            continue
        for path in sorted(root.glob("BENCH_*.json")):
            if path.resolve() in visited:
                continue
            visited.add(path.resolve())
            try:
                doc = json.loads(path.read_text())
            except (OSError, json.JSONDecodeError) as e:
                bad |= err(path, f"unreadable: {e}")
                continue
            checker = CHECKERS.get(path.name)
            if checker is None:
                bad |= err(path, "unknown BENCH_*.json with no registered schema "
                                 "(register a checker in tools/check_bench_json.py)")
                continue
            seen += 1
            bad |= checker(doc, path)
    if bad:
        return 1
    print(f"check_bench_json: {seen} bench dump(s) OK"
          + ("" if seen else " (none present — benches not run, fine)"))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
