#!/usr/bin/env python3
"""Render results/*.json (from `nmsparse table ...`) back to markdown for
EXPERIMENTS.md. Usage: python tools/results_to_md.py [results_dir]"""

import json
import os
import sys


def render(path: str) -> str:
    with open(path) as f:
        t = json.load(f)
    out = [f"### {t.get('title', os.path.basename(path))}", ""]
    header = t["header"]
    out.append("| " + " | ".join(header) + " |")
    out.append("|" + "---|" * len(header))
    for row in t["rows"]:
        out.append("| " + " | ".join(row) + " |")
    if t.get("note"):
        out.append(f"\n_{t['note']}_")
    return "\n".join(out)


def main() -> None:
    d = sys.argv[1] if len(sys.argv) > 1 else "results"
    names = sorted(n for n in os.listdir(d) if n.endswith(".json"))
    for n in names:
        print(render(os.path.join(d, n)))
        print()


if __name__ == "__main__":
    main()
