#!/usr/bin/env bash
# Tier-1 gate (DESIGN.md §5): build, test, and compile the benches.
# Every PR runs exactly this locally before merging:
#
#   tools/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."
ROOT="$(pwd)"

# The crate sources live under rust/; tolerate a manifest at either level.
if [ -f rust/Cargo.toml ]; then
  cd rust
elif [ ! -f Cargo.toml ]; then
  echo "ci: no Cargo.toml found at repo root or rust/ — cannot run the gate" >&2
  exit 1
fi
# Every smoke below writes its dump to an explicit path under OUTDIR so
# the schema scan at the end provably sees every emitted file — relying
# on each tool's default output path has already let a sweep dump land
# outside the scanned set once.
OUTDIR="$(pwd)"

cargo build --release
# Packed-stream smoke first, as a fast-fail: the compressed-domain
# invariants (pack->unpack bit-identity, LUT==loop combinadic, word==bit
# codec streams) gate everything downstream, and this one test binary
# finishes long before the full suite below (which runs it again as part
# of `cargo test`; the duplicate run is a few property suites, cheap).
cargo test -q --test packed_roundtrip
cargo test -q
cargo bench --no-run
# Serving smoke: a bounded loadgen run against a 2-replica ServerCore on
# the synthetic backend (no PJRT, no artifacts needed). Emits
# BENCH_serving.json, which the schema gate below then validates — this
# proves admission control, drain and the latency histogram end to end.
cargo run --release -q -- loadgen \
  --replicas 2 --queue-cap 64 --max-requests 96 --concurrency 8 \
  --forward-us 100 --out "$OUTDIR/BENCH_serving.json" \
  --trace "$OUTDIR/trace_serving.json"
# Native-decode smoke: seeded synthetic model, KV-cached vs full-context
# equivalence checked in-process (--check), output hash printed. Two runs
# must print the same hash — the determinism pin (no baked-in hash to go
# stale; the invariant is cross-run identity plus the in-process check).
DECODE_ARGS="decode --seed 11 --prompt-len 6 --max-new 12 --check"
H1="$(cargo run --release -q -- $DECODE_ARGS | grep '^hash ')"
H2="$(cargo run --release -q -- $DECODE_ARGS | grep '^hash ')"
if [ -z "$H1" ] || [ "$H1" != "$H2" ]; then
  echo "ci: native decode smoke failed (hash '$H1' vs '$H2')" >&2
  exit 1
fi
echo "ci: native decode smoke OK ($H1)"
# Tracing-bits pin: the same decode with span recording and Chrome
# export enabled must print the same hash — instrumentation never
# changes bits (DESIGN.md §2.14). The exported trace (and the loadgen
# one above) is validated for pairing/monotonicity by the schema block.
HTR="$(cargo run --release -q -- $DECODE_ARGS --trace "$OUTDIR/trace_decode.json" | grep '^hash ')"
if [ -z "$HTR" ] || [ "$HTR" != "$H1" ]; then
  echo "ci: traced decode smoke failed (traced '$HTR' vs untraced '$H1')" >&2
  exit 1
fi
echo "ci: traced decode smoke OK ($HTR)"
# Batched-decode smoke: 4 concurrent sliding-window sessions through the
# real NativeBackend (one StepBatch per tick) must hash-identical to the
# same 4 sessions run through the sequential sliding reference loops
# (--check additionally pins batched == sequential in-process).
BATCHED_ARGS="decode --seed 5 --lanes 4 --prompt-len 5 --max-new 10 --page-tokens 8 --check"
HB="$(cargo run --release -q -- $BATCHED_ARGS | grep '^hash ')"
HS="$(cargo run --release -q -- $BATCHED_ARGS --no-batch | grep '^hash ')"
if [ -z "$HB" ] || [ "$HB" != "$HS" ]; then
  echo "ci: batched decode smoke failed (batched '$HB' vs sequential '$HS')" >&2
  exit 1
fi
echo "ci: batched decode smoke OK ($HB)"
# Threaded-decode smoke: the same batched run on a 4-wide worker pool
# (--check pins batched == sequential in-process on the threaded engine)
# must hash-identical to the single-threaded run above — threading
# changes wall time, never bits (DESIGN.md §2.11).
THREAD_ARGS="decode --seed 5 --lanes 4 --prompt-len 5 --max-new 10 --page-tokens 8 --check"
HT="$(cargo run --release -q -- $THREAD_ARGS --threads 4 | grep '^hash ')"
H1T="$(cargo run --release -q -- $THREAD_ARGS --threads 1 | grep '^hash ')"
if [ -z "$HT" ] || [ "$HT" != "$H1T" ] || [ "$HT" != "$HB" ]; then
  echo "ci: threaded decode smoke failed (4 threads '$HT' vs 1 thread '$H1T')" >&2
  exit 1
fi
echo "ci: threaded decode smoke OK ($HT)"
# Blocked-prefill smoke: a long prompt (96 tokens, cropped to the tiny
# model's 64-position window) ingested per-token, in blocks of 1, and in
# blocks of 16 must print identical hashes — blocked prefill changes
# wall time, never bits (DESIGN.md §2.13); --check additionally pins the
# KV-cached loop against the full-context reference in-process.
PREFILL_ARGS="decode --seed 11 --prompt-len 96 --max-new 8 --check"
HP0="$(cargo run --release -q -- $PREFILL_ARGS | grep '^hash ')"
HP1="$(cargo run --release -q -- $PREFILL_ARGS --prefill-block 1 | grep '^hash ')"
HP16="$(cargo run --release -q -- $PREFILL_ARGS --prefill-block 16 | grep '^hash ')"
if [ -z "$HP0" ] || [ "$HP0" != "$HP1" ] || [ "$HP0" != "$HP16" ]; then
  echo "ci: blocked prefill smoke failed (per-token '$HP0' vs block 1 '$HP1' vs block 16 '$HP16')" >&2
  exit 1
fi
echo "ci: blocked prefill smoke OK ($HP0)"
# ...and the same batched path end-to-end through a 2-replica ServerCore
# (generate-heavy so every tick exercises step_batch).
cargo run --release -q -- loadgen \
  --backend native --replicas 2 --queue-cap 32 --max-requests 32 \
  --concurrency 4 --mode generate --max-new 6 --out ''
# Open-loop sweep smoke on the KV-cached native backend (2 rates, bounded)
# -> BENCH_serving_sweep.json, schema-gated below.
cargo run --release -q -- loadgen \
  --backend native --replicas 2 --queue-cap 32 --max-requests 40 \
  --sweep 200,400 --mode mixed --max-new 4 --out '' \
  --sweep-out "$OUTDIR/BENCH_serving_sweep.json"
# Continuous-batching smoke: the long-prompt/short-decode mix through 2
# native replicas with resumable prefill (8 positions per tick). Long
# prompts overflow the tiny engine's 64-position window, so this drives
# sliding-window crop + bounded prefill + decode interleaving end to end;
# the per-class latency split must come back populated. Non-BENCH_* name:
# this throwaway is asserted inline, not by the schema scan.
cargo run --release -q -- loadgen \
  --backend native --replicas 2 --queue-cap 64 --max-requests 32 \
  --concurrency 4 --mode longmix --max-new 4 --prefill-block 8 \
  --out longmix_smoke_serving.json
python3 - longmix_smoke_serving.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
total = doc["served"] + doc["rejected"]
assert total == 32, f"longmix smoke: accounting unbalanced ({total} != 32)"
assert doc["served"] > 0, "longmix smoke: nothing served"
assert doc["errors"] == 0, f"longmix smoke: {doc['errors']} errors"
classes = doc["classes"]
for name in ("long_prompt", "short_decode"):
    c = classes[name]
    assert c["count"] > 0, f"longmix smoke: class {name} empty"
    assert c["latency_ms"]["p99"] > 0, f"longmix smoke: {name} p99 not positive"
print(f"ci: longmix smoke OK (long {classes['long_prompt']['count']}, "
      f"short {classes['short_decode']['count']}, served {doc['served']})")
EOF
rm -f longmix_smoke_serving.json
# Chaos smoke: a fixed-seed fault plan (>=1 panic per replica) against 2
# synthetic replicas. Proves the supervisor end to end: the panicked
# replicas restart, every request reaches a terminal outcome, and the
# availability accounting balances. The dump uses a non-BENCH_* name so
# the schema scan below doesn't treat this throwaway as a bench artifact.
cargo run --release -q -- loadgen \
  --replicas 2 --queue-cap 64 --max-requests 96 --concurrency 8 \
  --forward-us 100 --chaos 7 --request-timeout-ms 2000 \
  --out chaos_smoke_serving.json
python3 - chaos_smoke_serving.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["restarts"] > 0, f"chaos smoke: no replica restarted ({doc['restarts']})"
total = doc["served"] + doc["rejected"]
assert total == 96, f"chaos smoke: accounting unbalanced ({total} != 96)"
for key in ("timeout_rate", "failure_rate", "rejection_rate"):
    assert 0.0 <= doc[key] <= 1.0, f"chaos smoke: {key} = {doc[key]} outside [0, 1]"
assert doc["timed_out"] + doc["failed"] <= doc["errors"], "chaos smoke: error taxonomy"
print(f"ci: chaos smoke OK (restarts {doc['restarts']}, retried {doc['retried']}, "
      f"timed_out {doc['timed_out']}, failed {doc['failed']})")
EOF
rm -f chaos_smoke_serving.json
# Wire-codec equivalence smoke (DESIGN.md §2.15): the same seeded longmix
# run roundtripped in-process through the JSON codec (buffered) and the
# binary codec with streamed generates must agree on every reply payload
# — served counts, zero errors, and the order-independent transcript
# hash — and the streamed run must observe incremental chunk frames
# before the terminal replies. Non-BENCH_* names: asserted inline, not
# by the schema scan.
WIRE_ARGS="loadgen --replicas 2 --queue-cap 64 --max-requests 48 \
  --concurrency 6 --mode longmix --max-new 4 --forward-us 100 --seed 7"
cargo run --release -q -- $WIRE_ARGS --codec json \
  --out codec_json_serving.json
cargo run --release -q -- $WIRE_ARGS --codec binary --stream \
  --out codec_binary_serving.json
python3 - codec_json_serving.json codec_binary_serving.json <<'EOF'
import json, sys
a = json.load(open(sys.argv[1]))
b = json.load(open(sys.argv[2]))
assert a["codec"] == "json" and b["codec"] == "binary", (a["codec"], b["codec"])
for doc, name in ((a, "json"), (b, "binary")):
    assert doc["rejected"] == 0, f"codec smoke: {name} shed {doc['rejected']}"
    assert doc["errors"] == 0, f"codec smoke: {name} run saw {doc['errors']} errors"
assert a["served"] == b["served"], \
    f"codec smoke: served diverged ({a['served']} vs {b['served']})"
assert a["transcript_hash"] == b["transcript_hash"], \
    f"codec smoke: reply transcripts diverged ({a['transcript_hash']} vs " \
    f"{b['transcript_hash']})"
assert a["stream_chunks"] == 0, "codec smoke: buffered run saw chunk frames"
assert b["stream_chunks"] > 0, "codec smoke: streamed run saw no chunk frames"
print(f"ci: wire codec smoke OK (served {a['served']}, transcript "
      f"{a['transcript_hash']}, {b['stream_chunks']} streamed chunks)")
EOF
rm -f codec_json_serving.json codec_binary_serving.json
# Weighted-fair smoke: a ~10:1 tenant traffic skew (seed-pinned to 76:12
# over 88 requests) at equal DRR dispatch weights through one synthetic
# replica with a real per-forward cost. The dump lands under the
# BENCH_serving.json name in its own directory so the schema scan's
# fairness gate judges the light tenant's queue-wait p95; the inline
# assertions pin that the gate had a real skew to judge.
mkdir -p fairness_smoke
cargo run --release -q -- loadgen \
  --replicas 1 --queue-cap 128 --max-requests 88 --concurrency 8 \
  --forward-us 500 --tenants 2:10,1 --seed 11 \
  --out fairness_smoke/BENCH_serving.json
python3 - fairness_smoke/BENCH_serving.json <<'EOF'
import json, sys
doc = json.load(open(sys.argv[1]))
ten = doc["tenants"]
assert ten["count"] == 2, f"fairness smoke: {ten['count']} tenants"
assert ten["weights"] == [1, 1], f"fairness smoke: weights {ten['weights']}"
heavy, light = ten["per_tenant"]
assert light["submitted"] > 0, "fairness smoke: light tenant saw no traffic"
assert heavy["submitted"] >= 4 * light["submitted"], \
    f"fairness smoke: skew too shallow ({heavy['submitted']} vs {light['submitted']})"
print(f"ci: fairness smoke OK (heavy {heavy['submitted']}, light "
      f"{light['submitted']}, qwait p95 heavy {heavy['queue_wait_ms']['p95']:.2f}ms "
      f"light {light['queue_wait_ms']['p95']:.2f}ms)")
EOF
python3 "$ROOT/tools/check_bench_json.py" fairness_smoke
rm -rf fairness_smoke
# Any bench dumps lying around must match the schemas the tables consume
# (absent files are fine — benches are optional here; unknown BENCH_*.json
# names or schema violations are not).
if command -v python3 >/dev/null 2>&1; then
  # First prove the gates themselves still reject bad dumps (inline
  # good/bad fixtures), then scan whatever dumps exist.
  python3 "$ROOT/tools/check_bench_json.py" --self-test
  python3 "$ROOT/tools/check_bench_json.py" "$ROOT" "$ROOT/rust" "$OUTDIR"
  # Same for the Chrome trace exports the smokes above wrote: prove the
  # validator still rejects broken traces, then validate the real ones.
  python3 "$ROOT/tools/check_trace_json.py" --self-test
  python3 "$ROOT/tools/check_trace_json.py" \
    "$OUTDIR/trace_decode.json" "$OUTDIR/trace_serving.json"
else
  echo "ci: python3 not found — skipping BENCH_*.json schema check"
fi
rm -f "$OUTDIR/trace_decode.json" "$OUTDIR/trace_serving.json"
echo "ci: tier-1 gate green"
