#!/usr/bin/env bash
# Tier-1 gate (DESIGN.md §5): build, test, and compile the benches.
# Every PR runs exactly this locally before merging:
#
#   tools/ci.sh
#
set -euo pipefail
cd "$(dirname "$0")/.."

# The crate sources live under rust/; tolerate a manifest at either level.
if [ -f rust/Cargo.toml ]; then
  cd rust
elif [ ! -f Cargo.toml ]; then
  echo "ci: no Cargo.toml found at repo root or rust/ — cannot run the gate" >&2
  exit 1
fi

cargo build --release
cargo test -q
cargo bench --no-run
echo "ci: tier-1 gate green"
