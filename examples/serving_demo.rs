//! Serving demo: spawn the `nmsparse serve` coordinator (two engine
//! replicas) as a child process, drive it as a client over the TCP JSON
//! protocol, and report per-request latencies plus the server's own
//! `{"op":"stats"}` view (p50/p95/p99 histogram, batch occupancy,
//! rejection rate) — the miniature of a production deployment of the
//! sparse model. For sustained load curves use `nmsparse loadgen`.
//!
//! ```bash
//! make build && cargo run --release --offline --example serving_demo
//! ```

use anyhow::{Context, Result};
use nmsparse::util::json;
use nmsparse::util::stats::TimingStats;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const ADDR: &str = "127.0.0.1:7451";

fn wait_for_server(child: &mut Child) -> Result<TcpStream> {
    for _ in 0..300 {
        if let Some(status) = child.try_wait()? {
            anyhow::bail!("server exited early: {status}");
        }
        match TcpStream::connect(ADDR) {
            Ok(s) => return Ok(s),
            Err(_) => std::thread::sleep(Duration::from_millis(200)),
        }
    }
    anyhow::bail!("server did not come up on {ADDR}")
}

fn roundtrip(
    reader: &mut BufReader<TcpStream>,
    writer: &mut TcpStream,
    req: &str,
) -> Result<(json::Json, Duration)> {
    let t0 = Instant::now();
    writer.write_all(req.as_bytes())?;
    writer.write_all(b"\n")?;
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let dt = t0.elapsed();
    let j = json::parse(line.trim()).map_err(|e| anyhow::anyhow!("{e}"))?;
    anyhow::ensure!(
        j.get("ok").and_then(|o| o.as_bool()).unwrap_or(false),
        "server error: {line}"
    );
    Ok((j, dt))
}

fn main() -> Result<()> {
    let bin = std::env::var("NMSPARSE_BIN").unwrap_or("target/release/nmsparse".into());
    println!("spawning {bin} serve on {ADDR} (8:16 / S-PTS, 2 replicas)...");
    let mut child = Command::new(&bin)
        .args([
            "serve", "--addr", ADDR, "--pattern", "8:16", "--method", "S-PTS",
            "--replicas", "2",
        ])
        .stdout(Stdio::inherit())
        .stderr(Stdio::inherit())
        .spawn()
        .context("spawning server (run `make build` first)")?;

    let result = (|| -> Result<()> {
        let stream = wait_for_server(&mut child)?;
        stream.set_nodelay(true).ok();
        let mut writer = stream.try_clone()?;
        let mut reader = BufReader::new(stream);

        // Ping.
        let (pong, dt) = roundtrip(&mut reader, &mut writer, r#"{"op":"ping"}"#)?;
        println!(
            "ping: variant={} method={} ({:.1}ms)",
            pong.get("variant").and_then(|v| v.as_str()).unwrap_or("?"),
            pong.get("method").and_then(|v| v.as_str()).unwrap_or("?"),
            dt.as_secs_f64() * 1e3
        );

        // Scoring traffic (uses world facts via the boolq surface form).
        let world_text = std::fs::read_to_string("artifacts/data/world.json")?;
        let world = json::parse(&world_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let entities = world.req("entities")?.as_arr().unwrap();
        let mut score_lat = Vec::new();
        let mut correct = 0usize;
        let n = entities.len().min(24);
        for e in &entities[..n] {
            let name = e.req("name")?.as_str().unwrap();
            let loc = e.req("location")?.as_str().unwrap();
            let q = format!("does the {name} live in the {loc} ?");
            let req_yes = format!(r#"{{"op":"score","text":"{q}","choice":"yes"}}"#);
            let req_no = format!(r#"{{"op":"score","text":"{q}","choice":"no"}}"#);
            let (ry, d1) = roundtrip(&mut reader, &mut writer, &req_yes)?;
            let (rn, d2) = roundtrip(&mut reader, &mut writer, &req_no)?;
            score_lat.push(d1);
            score_lat.push(d2);
            let sy = ry.get("score").and_then(|s| s.as_f64()).unwrap_or(f64::MIN);
            let sn = rn.get("score").and_then(|s| s.as_f64()).unwrap_or(f64::MAX);
            correct += (sy > sn) as usize;
        }
        println!(
            "scored {n} yes/no facts: {}/{n} correct under 8:16 S-PTS",
            correct
        );
        println!("score latency: {}", TimingStats::from_durations(&score_lat).summary());

        // Generation traffic.
        let mut gen_lat = Vec::new();
        for e in &entities[..4.min(entities.len())] {
            let name = e.req("name")?.as_str().unwrap();
            let req = format!(
                r#"{{"op":"generate","text":"where does the {name} live ? in","max_new":6}}"#
            );
            let (r, dt) = roundtrip(&mut reader, &mut writer, &req)?;
            gen_lat.push(dt);
            println!(
                "generate[{name}]: '{}' ({:.0}ms)",
                r.get("text").and_then(|t| t.as_str()).unwrap_or("?"),
                dt.as_secs_f64() * 1e3
            );
        }
        println!("generate latency: {}", TimingStats::from_durations(&gen_lat).summary());

        // The server's own measured view of the run.
        let (stats, _) = roundtrip(&mut reader, &mut writer, r#"{"op":"stats"}"#)?;
        let lat = stats.req("latency_ms")?;
        let ms = |j: &json::Json, k: &str| j.get(k).and_then(|x| x.as_f64()).unwrap_or(0.0);
        println!(
            "server stats: served {} (rejected {}) | p50 {:.2}ms p95 {:.2}ms p99 {:.2}ms | \
             occupancy {:.2}",
            ms(&stats, "served"),
            ms(&stats, "rejected"),
            ms(lat, "p50"),
            ms(lat, "p95"),
            ms(lat, "p99"),
            ms(&stats, "batch_occupancy"),
        );
        Ok(())
    })();

    child.kill().ok();
    child.wait().ok();
    result?;
    println!("serving demo OK");
    Ok(())
}
