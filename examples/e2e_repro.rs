//! End-to-end reproduction driver (the EXPERIMENTS.md workhorse).
//!
//! Exercises the full three-layer stack on the real SynthLang workload:
//! loads the AOT artifacts through PJRT, runs the paper's headline
//! experiments (activation-vs-weight, the pattern-flexibility sweep, the
//! best error-mitigation methods and the IFEval analog), checks the
//! paper's qualitative claims hold, and reports throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example e2e_repro [-- --examples 64]
//! ```

use anyhow::Result;
use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::evalharness::{self, ifeval::eval_ifeval};
use nmsparse::sparsity::Pattern;
use nmsparse::tables::TableCtx;
use std::time::Instant;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let examples = args
        .iter()
        .position(|a| a == "--examples")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(64usize);
    let t0 = Instant::now();
    let mut ctx = TableCtx::open("artifacts", "artifacts/data", examples)?;
    println!(
        "model: {} params | trained valid ppl {:.3} | eval shape {}x{}\n",
        ctx.coord.pool.manifest.dims.num_params,
        ctx.coord.pool.manifest.train_valid_ppl,
        ctx.coord.pool.manifest.dims.batch,
        ctx.coord.pool.manifest.dims.seq,
    );

    // ---- headline 1: dense baseline is meaningfully above chance ----
    let (base, base_mean) = ctx.eval_core(&MethodConfig::dense())?;
    println!("dense core-suite accuracies:");
    for r in &base {
        println!("  {:<18} {:.4} (n={})", r.task, r.accuracy, r.n);
    }
    assert!(
        base_mean > 0.55,
        "dense baseline too weak ({base_mean:.3}) — retrain with more steps"
    );

    // ---- headline 2: activation beats weight sparsity ----
    // Checked at 70% sparsity where the paper's separation is decisive
    // (19.6% vs 43.4%); at 50% both drops are small and sampling noise on a
    // small suite can flip the order, so u50 is reported informationally.
    let u50 = Pattern::Unstructured { keep_pct: 50 };
    let u70 = Pattern::Unstructured { keep_pct: 30 };
    let act_drop50 = ctx.drop_core(&MethodConfig::act(u50))?;
    let wt_drop50 = ctx.drop_core(&MethodConfig::wt(u50))?;
    let act_drop = ctx.drop_core(&MethodConfig::act(u70))?;
    let wt_drop = ctx.drop_core(&MethodConfig::wt(u70))?;
    println!("\nu50: ACT drop {act_drop50:.2}% vs WT drop {wt_drop50:.2}% (paper: 2.3% vs 11.1%)");
    println!("u70: ACT drop {act_drop:.2}% vs WT drop {wt_drop:.2}% (paper: 19.6% vs 43.4%)");

    // ---- headline 3: flexibility ordering 2:4 -> 16:32 -> u50 ----
    println!("\npattern sweep (ACT):");
    let mut drops = Vec::new();
    for key in ["2:4", "4:8", "8:16", "16:32", "u50"] {
        let d = ctx.drop_core(&MethodConfig::act(Pattern::parse(key)?))?;
        let paper = nmsparse::tables::paper_ref::fig2_drop(key);
        println!("  {key:>6}: drop {d:.2}%  (paper: {paper})");
        drops.push((key, d));
    }

    // ---- headline 4: error mitigation helps at 8:16 ----
    let p816 = Pattern::NM { n: 8, m: 16 };
    println!("\nerror mitigation at 8:16:");
    for name in ["ACT", "S-PTS", "D-PTS", "VAR", "CLACT", "Amber-Pruner"] {
        let d = ctx.drop_core(&MethodConfig::by_name(name, p816)?)?;
        println!("  {name:<14} drop {d:.2}%");
    }

    // ---- headline 5: generative (IFEval) degrades harder than QA ----
    let set = ctx.ifeval_set()?;
    let vocab = ctx.vocab.clone();
    let orig = eval_ifeval(&ctx.coord, &MethodConfig::dense(), &set, &vocab, 32, 10)?;
    let spts = eval_ifeval(
        &ctx.coord,
        &MethodConfig::by_name("S-PTS", p816)?,
        &set,
        &vocab,
        32,
        10,
    )?;
    println!(
        "\nifeval PS/PL: dense {:.3}/{:.3} -> 8:16 S-PTS {:.3}/{:.3}",
        orig.strict, orig.loose, spts.strict, spts.loose
    );

    // ---- shape assertions (the paper's claims) ----
    let get = |k: &str| drops.iter().find(|(key, _)| *key == k).unwrap().1;
    let mut claims: Vec<(&str, bool)> = vec![
        ("ACT(u70) degrades less than WT(u70)", act_drop < wt_drop),
        ("16:32 beats 2:4", get("16:32") < get("2:4")),
        ("8:16 beats 2:4", get("8:16") < get("2:4")),
        ("u50 is the floor of the 50%-density sweep", get("u50") <= get("2:4")),
        ("dense IFEval >= sparse IFEval", orig.strict >= spts.strict),
    ];
    println!("\nclaim checks:");
    let mut ok_all = true;
    for (claim, ok) in claims.drain(..) {
        println!("  [{}] {claim}", if ok { "ok" } else { "FAIL" });
        ok_all &= ok;
    }

    let dt = t0.elapsed().as_secs_f64();
    println!(
        "\ne2e done in {dt:.1}s: {} ({:.1} forwards/s)",
        ctx.coord.stats.summary(),
        ctx.coord.stats.forwards() as f64 / dt
    );
    anyhow::ensure!(ok_all, "some paper-shape claims failed");
    println!("ALL CLAIM CHECKS PASSED");
    Ok(())
}
