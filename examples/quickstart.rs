//! Quickstart: load the artifacts, ask one question, compare dense vs
//! sparse answers.
//!
//! ```bash
//! make artifacts && cargo run --release --offline --example quickstart
//! ```

use anyhow::Result;
use nmsparse::coordinator::methods::MethodConfig;
use nmsparse::coordinator::Coordinator;
use nmsparse::sparsity::Pattern;
use nmsparse::synthlang::vocab::Vocab;
use std::path::Path;

fn main() -> Result<()> {
    let artifacts = std::env::var("NMSPARSE_ARTIFACTS").unwrap_or("artifacts".into());
    let coord = Coordinator::open(Path::new(&artifacts))?;
    let vocab = Vocab::synthlang();

    // Pull a real question out of the generated world: ask about entity 0.
    let world_json = std::fs::read_to_string(format!("{artifacts}/data/world.json"))?;
    let world = nmsparse::util::json::parse(&world_json).map_err(|e| anyhow::anyhow!("{e}"))?;
    let e0 = &world.req("entities")?.as_arr().unwrap()[0];
    let name = e0.req("name")?.as_str().unwrap();
    let location = e0.req("location")?.as_str().unwrap();

    let question = format!("does the {name} live in the {location} ?");
    println!("Q: {question}   (ground truth: yes)\n");

    let configs = [
        MethodConfig::dense(),
        MethodConfig::act(Pattern::NM { n: 2, m: 4 }),
        MethodConfig::by_name("S-PTS", Pattern::NM { n: 8, m: 16 })?,
    ];
    println!("{:<24} {:>12} {:>12} verdict", "config", "logp(yes)", "logp(no)");
    for cfg in &configs {
        let ctx = vocab.encode(&question)?;
        let rows: Vec<(Vec<u32>, (usize, usize))> = ["yes", "no"]
            .iter()
            .map(|ans| {
                let mut row = ctx.clone();
                let start = row.len();
                row.extend(vocab.encode(ans).unwrap());
                (row, (start, start + 1))
            })
            .collect();
        let scores = coord.score_rows(cfg, &rows)?;
        let verdict = if scores[0] > scores[1] { "yes ✓" } else { "no ✗" };
        println!(
            "{:<24} {:>12.4} {:>12.4} {}",
            format!("{}/{}", cfg.variant_key, cfg.id),
            scores[0],
            scores[1],
            verdict
        );
    }

    // And one generation.
    let prompt = format!("where does the {name} live ? in");
    let out = coord.generate(
        &MethodConfig::dense(),
        &[vocab.encode(&prompt)?],
        6,
        &[vocab.id(".")?],
    )?;
    println!("\ngenerate> {prompt} {}", vocab.decode(&out[0]));
    Ok(())
}
