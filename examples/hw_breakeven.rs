//! Hardware break-even explorer (Appendix A, no artifacts needed).
//!
//! Sweeps the EDP model over sparsification-overhead and utilization
//! assumptions, prints the break-even hardware speedup `k` per pattern, and
//! the metadata/flexibility trade-off that motivates 8:16 as the paper's
//! recommended target.
//!
//! ```bash
//! cargo run --release --offline --example hw_breakeven
//! ```

use nmsparse::hwmodel::{assess, incremental_die_area_pct, EdpModel};
use nmsparse::metadata::{bits_per_element, Encoding};
use nmsparse::sparsity::Pattern;
use nmsparse::tables::{
    load_measured_overhead, load_packed_bench, OVERHEAD_BENCH_FILE, PACKED_BENCH_FILE,
};
use std::path::Path;

fn main() {
    // Measured compressed-stream footprints (written by `cargo bench --
    // substrate`): per-pattern bytes/row of the packed representation.
    // When present, the EDP analysis below uses the *measured* bandwidth
    // ratio r = dense/packed instead of the theoretical 1/density.
    let packed = load_packed_bench(Path::new(PACKED_BENCH_FILE));
    let measured_r = |pat: &str| {
        packed.as_ref().and_then(|rows| {
            rows.iter()
                .find(|r| r.pattern == pat)
                .map(|r| r.measured_bandwidth_reduction)
        })
    };
    println!("== flexibility vs metadata (the §1 argument) ==");
    println!(
        "{:<8} {:>16} {:>14} {:>12} {:>10}",
        "pattern", "layouts/block", "bits/elt", "vs 2:4", "die area"
    );
    for (n, m) in [(2u32, 4u32), (4, 8), (8, 16), (16, 32)] {
        let p = Pattern::NM { n, m };
        let layouts = p.layouts_per_block().unwrap();
        let bpe = bits_per_element(n as u64, m as u64, Encoding::Combinadic);
        let rel = bpe / 0.75;
        println!(
            "{:<8} {:>16} {:>14.4} {:>11.1}% {:>9.2}%",
            p.to_string(),
            layouts,
            bpe,
            (rel - 1.0) * 100.0,
            incremental_die_area_pct(p)
        );
    }

    println!("\n== EDP break-even sweep (Appendix A.1) ==");
    // Bandwidth ratio: measured from the packed 8:16 stream when the bench
    // has run, the paper's theoretical 2.0 otherwise.
    let r_816 = measured_r("8:16").unwrap_or(2.0);
    println!(
        "bandwidth ratio r = {:.3} ({})",
        r_816,
        if measured_r("8:16").is_some() {
            "measured: dense/packed bytes per row, BENCH_packed.json"
        } else {
            "theoretical 1/density — run `cargo bench -- substrate` to measure"
        }
    );
    println!(
        "{:<10} {:>8} {:>8} {:>12} {:>12}",
        "overhead", "util", "r", "EDP gain", "k required"
    );
    for overhead in [0.15, 0.30, 0.45] {
        for util in [0.75, 0.85, 0.95] {
            let m = EdpModel {
                bandwidth_reduction: r_816,
                utilization: util,
                overhead,
            };
            println!(
                "{:<10.2} {:>8.2} {:>8.2} {:>11.3}x {:>12.3}",
                overhead,
                util,
                m.bandwidth_reduction,
                m.edp_improvement(),
                m.breakeven_k()
            );
        }
    }
    let mut paper = EdpModel::paper_default();
    paper.bandwidth_reduction = r_816;
    println!(
        "\npaper parameterization at r={:.2}: EDP gain {:.3}x, break-even k > {:.2} \
         (conservative bar {:.1}x)",
        r_816,
        paper.edp_improvement(),
        paper.breakeven_k(),
        EdpModel::CONSERVATIVE_K
    );

    if let Some(rows) = &packed {
        println!("\n== measured packed activation I/O ({PACKED_BENCH_FILE}) ==");
        println!(
            "{:<10} {:>14} {:>14} {:>10} {:>14} {:>12}",
            "pattern", "dense B/row", "packed B/row", "r", "codec xbitloop", "EDP gain"
        );
        for row in rows {
            let m = EdpModel::paper_default()
                .with_measured_bandwidth(row.dense_bytes_per_row, row.packed_bytes_per_row);
            println!(
                "{:<10} {:>14.0} {:>14.0} {:>10.3} {:>14} {:>11.3}x",
                row.pattern,
                row.dense_bytes_per_row,
                row.packed_bytes_per_row,
                row.measured_bandwidth_reduction,
                if row.codec_word_speedup > 0.0 {
                    format!("{:.1}x", row.codec_word_speedup)
                } else {
                    "-".into()
                },
                m.edp_improvement(),
            );
        }
    }

    // Measured software baseline: `cargo bench -- tables` times the fused
    // Sparsifier against end-to-end forward time per pattern and writes the
    // overhead fractions; use them as alpha instead of the analytic 0.3.
    match load_measured_overhead(Path::new(OVERHEAD_BENCH_FILE)) {
        Some(measured) => {
            println!("\n== measured software-overhead baseline ({OVERHEAD_BENCH_FILE}) ==");
            println!(
                "{:<10} {:>12} {:>12} {:>12}",
                "pattern", "alpha (sw)", "EDP gain", "k required"
            );
            for (pat, frac) in &measured {
                // Prefer the measured packed bandwidth ratio per pattern;
                // theoretical 1/density only when the packed bench is absent.
                let r = measured_r(pat).unwrap_or_else(|| match Pattern::parse(pat) {
                    Ok(p) => 1.0 / p.density().max(1e-9),
                    Err(_) => 2.0,
                });
                let m = EdpModel {
                    bandwidth_reduction: r,
                    utilization: 0.85,
                    overhead: *frac,
                };
                println!(
                    "{:<10} {:>12.4} {:>11.3}x {:>12.3}",
                    pat,
                    frac,
                    m.edp_improvement(),
                    m.breakeven_k()
                );
            }
        }
        None => println!(
            "\n(no {OVERHEAD_BENCH_FILE} — run `cargo bench -- tables` with artifacts \
             to add a measured software-overhead baseline)"
        ),
    }

    println!("\n== qualitative complexity (Table 6) ==");
    for p in [Pattern::NM { n: 2, m: 4 }, Pattern::NM { n: 8, m: 16 }] {
        let a = assess(p);
        println!(
            "{}: metadata {} ({:.3} b/elt), controller {} ({}-bit), bandwidth {}, NRE {}",
            p,
            a.metadata_rating,
            a.metadata_bits_per_elt,
            a.controller_rating,
            a.controller_bits,
            a.bandwidth_rating,
            a.nre_rating
        );
    }
    println!("\nconclusion: 8:16 buys ~10x flexibility for +16.7% metadata and <2% die area");
}
