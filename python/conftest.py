"""Make `pytest python/tests/` work from the repo root: the compile package
lives under python/, which is the import root for the build pipeline."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
