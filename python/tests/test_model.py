"""L2 correctness: model shapes, masking, kernel-vs-oracle at model level,
training step sanity and the tensorstore format."""

import os
import tempfile

import numpy as np
import pytest

import jax.numpy as jnp

from compile import tensorstore
from compile.kernels.ref import SparsitySpec
from compile.model import (
    SITES,
    MethodInputs,
    ModelConfig,
    forward,
    init_params,
    lm_loss,
    num_params,
    param_names,
    param_shape,
)

CFG = ModelConfig(vocab=160, d_model=64, n_layers=2, n_heads=2, ffn=128)
PARAMS = init_params(CFG, seed=0)
RNG = np.random.default_rng(0)


def toks(b, t):
    return jnp.asarray(RNG.integers(0, CFG.vocab, size=(b, t)), jnp.int32)


def test_param_inventory():
    names = param_names(CFG)
    assert len(names) == 3 + CFG.n_layers * (len(SITES) + 2)
    assert names == sorted(names)
    total = num_params(CFG)
    assert total == sum(int(np.prod(param_shape(CFG, n))) for n in names)
    assert param_shape(CFG, "layers.0.down.w") == (CFG.d_model, CFG.ffn)
    assert param_shape(CFG, "layers.1.gate.w") == (CFG.ffn, CFG.d_model)


def test_forward_shapes():
    tokens = toks(3, 12)
    lens = jnp.asarray([12, 5, 1], jnp.int32)
    lp, ll = forward(CFG, PARAMS, tokens, lens, SparsitySpec("dense"))
    assert lp.shape == (3, 12)
    assert ll.shape == (3, CFG.vocab)
    assert np.asarray(lp)[:, -1].tolist() == [0.0, 0.0, 0.0]
    # Logprobs are valid (<= 0) at scored positions.
    assert (np.asarray(lp)[:, :-1] <= 1e-6).all()


def test_padding_does_not_change_prefix_outputs():
    # Changing tokens beyond `lens` must not change last_logits.
    tokens = np.asarray(toks(2, 16))
    lens = jnp.asarray([8, 8], jnp.int32)
    t1 = jnp.asarray(tokens)
    tokens2 = tokens.copy()
    tokens2[:, 10:] = 7  # mutate padding region
    t2 = jnp.asarray(tokens2)
    _, ll1 = forward(CFG, PARAMS, t1, lens, SparsitySpec("dense"))
    _, ll2 = forward(CFG, PARAMS, t2, lens, SparsitySpec("dense"))
    np.testing.assert_allclose(np.asarray(ll1), np.asarray(ll2), rtol=1e-5, atol=1e-5)


def test_causality():
    # Changing a future token must not change past logprobs.
    tokens = np.asarray(toks(1, 16))
    lens = jnp.asarray([16], jnp.int32)
    lp1, _ = forward(CFG, PARAMS, jnp.asarray(tokens), lens, SparsitySpec("dense"))
    tokens2 = tokens.copy()
    tokens2[0, 12] = (tokens2[0, 12] + 1) % CFG.vocab
    lp2, _ = forward(CFG, PARAMS, jnp.asarray(tokens2), lens, SparsitySpec("dense"))
    # Positions strictly before 11 predict tokens <= 11 from prefixes <= 11:
    # unchanged. (tgt_lp[t] involves token t+1, so t <= 10 is unaffected.)
    np.testing.assert_allclose(
        np.asarray(lp1)[0, :11], np.asarray(lp2)[0, :11], rtol=1e-5, atol=1e-5
    )
    assert abs(float(lp1[0, 11] - lp2[0, 11])) > 0  # the changed prediction


@pytest.mark.parametrize("spec_key", ["2:4", "8:16", "u50"])
def test_model_kernel_matches_oracle(spec_key):
    tokens = toks(2, 10)
    lens = jnp.asarray([10, 6], jnp.int32)
    spec = SparsitySpec.parse(spec_key)
    mi = MethodInputs.neutral(CFG)
    mi.shift_mode = 1.0
    mi.use_var = 1.0
    a = forward(CFG, PARAMS, tokens, lens, spec, mi, use_kernel=True)
    b = forward(CFG, PARAMS, tokens, lens, spec, mi, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]), rtol=2e-3, atol=2e-3)


def test_rsparse_model_path():
    tokens = toks(2, 8)
    lens = jnp.asarray([8, 8], jnp.int32)
    spec = SparsitySpec.parse("8:16")
    mi = MethodInputs.neutral(CFG, rank=8)
    a = forward(CFG, PARAMS, tokens, lens, spec, mi, rsparse=True, use_kernel=True)
    b = forward(CFG, PARAMS, tokens, lens, spec, mi, rsparse=True, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(b[0]), rtol=2e-3, atol=2e-4)


def test_disabled_sites_recover_dense():
    # All sites disabled == dense forward.
    tokens = toks(2, 8)
    lens = jnp.asarray([8, 8], jnp.int32)
    mi = MethodInputs.neutral(CFG)
    for k in mi.enable:
        mi.enable[k] = jnp.zeros((), jnp.float32)
    a = forward(CFG, PARAMS, tokens, lens, SparsitySpec.parse("2:4"), mi)
    d = forward(CFG, PARAMS, tokens, lens, SparsitySpec("dense"))
    np.testing.assert_allclose(np.asarray(a[0]), np.asarray(d[0]), rtol=2e-4, atol=2e-4)


def test_sparsity_degrades_loss():
    # Aggressive sparsity must hurt the LM loss of a random model less
    # than... actually for a RANDOM model effects are small; instead check
    # the forward outputs differ and remain finite.
    tokens = toks(2, 8)
    lens = jnp.asarray([8, 8], jnp.int32)
    d = forward(CFG, PARAMS, tokens, lens, SparsitySpec("dense"))
    s = forward(CFG, PARAMS, tokens, lens, SparsitySpec.parse("2:4"))
    assert np.isfinite(np.asarray(s[0])).all()
    assert np.abs(np.asarray(d[1]) - np.asarray(s[1])).max() > 1e-4


def test_lm_loss_near_uniform_at_init():
    tokens = toks(4, 16)
    loss = float(lm_loss(CFG, PARAMS, tokens))
    assert abs(loss - np.log(CFG.vocab)) < 1.0


def test_training_reduces_loss():
    from compile.train import train

    # A tiny repetitive stream should be learned very fast.
    stream = np.tile(np.arange(12, dtype=np.int32), 600)
    params, history = train(
        ModelConfig(vocab=32, d_model=32, n_layers=1, n_heads=2, ffn=64),
        stream,
        steps=30,
        batch=8,
        seq=24,
        log_every=29,
    )
    assert history[-1][1] < history[0][1] * 0.5, history


def test_tensorstore_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        stem = os.path.join(d, "ckpt")
        data = {
            "a.w": RNG.normal(size=(4, 6)).astype(np.float32),
            "b": np.asarray([1.5, -2.5], np.float32),
            "s": np.float32(3.25),
        }
        tensorstore.save(stem, data)
        back = tensorstore.load(stem)
        assert set(back) == set(data)
        np.testing.assert_array_equal(back["a.w"], data["a.w"])
        np.testing.assert_array_equal(back["b"], data["b"])
        assert back["s"].shape == ()
        assert float(back["s"]) == 3.25


def test_method_input_names_order_is_stable():
    from compile.aot import method_input_names

    a = method_input_names(CFG, False, 0)
    b = method_input_names(CFG, False, 0)
    assert a == b
    assert a[0][0] == "m.eta.l0.q"
    assert a[-1][0] == "m.flag.use_var"
    r = method_input_names(CFG, True, 16)
    assert r[0][0] == "m.u.l0.q"
    assert r[0][1] == (CFG.d_model, 16)
    assert all(not n.startswith("m.flag") for n, _ in r)
