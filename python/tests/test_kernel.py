"""L1 correctness: the Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, patterns, flags and tile sizes; assert_allclose
against ref.py is THE correctness signal for the kernel (the rust side then
pins the same semantics via golden vectors in rust/tests/).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.nm_sparse import rsparse_linear, sparse_linear
from compile.kernels.ref import (
    SparsitySpec,
    clact_colnorm,
    nm_mask,
    rsparse_linear_ref,
    sparse_linear_ref,
    topk_row_mask,
)

RNG = np.random.default_rng(1234)


def rand(shape, scale=1.0, offset=0.0):
    return jnp.asarray(
        (RNG.normal(size=shape) * scale + offset).astype(np.float32)
    )


# ---------------------------------------------------------------- nm_mask


@given(
    m=st.sampled_from([4, 8, 16, 32]),
    blocks=st.integers(1, 6),
    rows=st.integers(1, 5),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=60, deadline=None)
def test_nm_mask_exactly_n_per_block(m, blocks, rows, seed):
    n = 1 + seed % m
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(rng.normal(size=(rows, blocks * m)).astype(np.float32))
    mask = np.asarray(nm_mask(scores, n, m))
    per_block = mask.reshape(rows, blocks, m).sum(axis=-1)
    assert (per_block == n).all()


def test_nm_mask_tie_break_low_index():
    scores = jnp.asarray([[1.0, 1.0, 1.0, 1.0]])
    mask = np.asarray(nm_mask(scores, 2, 4))
    assert mask.tolist() == [[1.0, 1.0, 0.0, 0.0]]


def test_nm_mask_keeps_largest():
    scores = jnp.asarray([[0.1, 5.0, 3.0, 0.2, 9.0, 1.0, 2.0, 8.0]])
    mask = np.asarray(nm_mask(scores, 2, 4))
    assert mask.tolist() == [[0.0, 1.0, 1.0, 0.0, 1.0, 0.0, 0.0, 1.0]]


@given(
    h=st.sampled_from([16, 32, 64]),
    keep_pct=st.sampled_from([10, 30, 50, 80]),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=40, deadline=None)
def test_topk_row_mask_density(h, keep_pct, seed):
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(np.abs(rng.normal(size=(3, h))).astype(np.float32))
    mask = np.asarray(topk_row_mask(scores, keep_pct / 100.0))
    k = round(h * keep_pct / 100.0)
    # Ties may overkeep; with continuous scores this is exact.
    assert (mask.sum(axis=-1) == k).all()


# ------------------------------------------------------- kernel vs oracle


@given(
    spec_key=st.sampled_from(["2:4", "4:8", "8:16", "16:32", "u50", "u70", "u20"]),
    rows=st.integers(1, 24),
    h=st.sampled_from([32, 64]),
    out=st.sampled_from([8, 48]),
    tile_r=st.sampled_from([4, 8, 64]),
    shift_mode=st.sampled_from([0.0, 1.0, 2.0]),
    use_var=st.sampled_from([0.0, 1.0]),
    use_clact=st.sampled_from([0.0, 1.0]),
    offset=st.sampled_from([0.0, 3.0]),
    seed=st.integers(0, 1_000),
)
@settings(max_examples=40, deadline=None)
def test_kernel_matches_oracle(
    spec_key, rows, h, out, tile_r, shift_mode, use_var, use_clact, offset, seed
):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.normal(size=(rows, h)) + offset).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(out, h)).astype(np.float32))
    eta = jnp.asarray((rng.normal(size=(h,)) * 0.2).astype(np.float32))
    cscale = jnp.asarray(np.abs(rng.normal(size=(h,)) + 1.0).astype(np.float32))
    lsw = jnp.asarray((1.0 + 0.1 * rng.normal(size=(h,))).astype(np.float32))
    colnorm = clact_colnorm(x)
    spec = SparsitySpec.parse(spec_key)
    kw = dict(
        eta=eta, cscale=cscale, lsw=lsw, colnorm=colnorm,
        shift_mode=shift_mode, use_var=use_var, use_clact=use_clact,
    )
    a = sparse_linear_ref(x, w, spec, **kw)
    b = sparse_linear(x, w, spec, tile_r=tile_r, **kw)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-4)


def test_kernel_disable_bypasses():
    x = rand((6, 32), offset=2.0)
    w = rand((16, 32))
    spec = SparsitySpec.parse("2:4")
    y = sparse_linear(x, w, spec, enable=0.0, tile_r=4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=2e-4, atol=2e-4)


def test_dense_spec_is_plain_matmul():
    x = rand((5, 16))
    w = rand((8, 16))
    y = sparse_linear(x, w, SparsitySpec("dense"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=1e-5, atol=1e-5)


def test_sparsity_actually_reduces_information():
    # Pruned output must differ from dense for generic inputs.
    x = rand((8, 64))
    w = rand((32, 64))
    dense = np.asarray(x @ w.T)
    pruned = np.asarray(sparse_linear(x, w, SparsitySpec.parse("2:4")))
    assert np.abs(dense - pruned).max() > 1e-3


@given(
    spec_key=st.sampled_from(["2:4", "8:16"]),
    rank=st.sampled_from([4, 16]),
    rows=st.integers(1, 12),
    seed=st.integers(0, 1_000),
)
@settings(max_examples=20, deadline=None)
def test_rsparse_kernel_matches_oracle(spec_key, rank, rows, seed):
    rng = np.random.default_rng(seed)
    h, out = 32, 24
    x = jnp.asarray(rng.normal(size=(rows, h)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(out, h)).astype(np.float32))
    u = jnp.asarray(rng.normal(size=(out, rank)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(rank, h)).astype(np.float32))
    spec = SparsitySpec.parse(spec_key)
    a = rsparse_linear_ref(x, w, u, v, spec)
    b = rsparse_linear(x, w, u, v, spec, tile_r=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-4)


def test_rsparse_full_rank_recovers_dense():
    # With U V == W, R-Sparse output equals the dense output exactly:
    # sigma(X) W^T + (X - sigma(X)) W^T = X W^T.
    rng = np.random.default_rng(7)
    h, out = 16, 16
    w_np = rng.normal(size=(out, h)).astype(np.float32)
    uu, ss, vv = np.linalg.svd(w_np)
    u = jnp.asarray((uu * ss).astype(np.float32))
    v = jnp.asarray(vv.astype(np.float32))
    x = jnp.asarray(rng.normal(size=(8, h)).astype(np.float32))
    w = jnp.asarray(w_np)
    y = rsparse_linear(x, w, u, v, SparsitySpec.parse("2:4"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w.T), rtol=1e-3, atol=1e-3)


# ------------------------------------------------- transform semantics


def test_dpts_improves_shifted_reconstruction():
    # The paper's motivation: centering before pruning preserves shifted
    # distributions (D-PTS beats plain ACT on mean-10 activations).
    rng = np.random.default_rng(3)
    x = jnp.asarray((rng.normal(size=(32, 64)) + 10.0).astype(np.float32))
    w = jnp.eye(64, dtype=jnp.float32)
    spec = SparsitySpec.parse("2:4")
    dense = np.asarray(x @ w.T)
    act = np.asarray(sparse_linear_ref(x, w, spec))
    dpts = np.asarray(sparse_linear_ref(x, w, spec, shift_mode=1.0))
    err_act = ((act - dense) ** 2).mean()
    err_dpts = ((dpts - dense) ** 2).mean()
    assert err_dpts < err_act * 0.5, (err_dpts, err_act)


def test_var_restores_output_scale():
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(16, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32, 64)).astype(np.float32))
    spec = SparsitySpec.parse("2:4")
    dense_norm = float(jnp.linalg.norm(x @ w.T))
    plain = float(jnp.linalg.norm(sparse_linear_ref(x, w, spec)))
    var = float(jnp.linalg.norm(sparse_linear_ref(x, w, spec, use_var=1.0)))
    # VAR should bring the output norm closer to dense than plain pruning.
    assert abs(var - dense_norm) < abs(plain - dense_norm)


def test_clact_differs_from_act_selection():
    # With skewed column energies CLACT must pick differently than ACT.
    rng = np.random.default_rng(5)
    x_np = rng.normal(size=(8, 16)).astype(np.float32)
    x_np[:, 0] *= 10.0  # huge column energy on channel 0
    x = jnp.asarray(x_np)
    cn = clact_colnorm(x)
    act_mask = np.asarray(nm_mask(jnp.abs(x), 2, 4))
    clact_mask = np.asarray(nm_mask(jnp.abs(x) * cn, 2, 4))
    assert (act_mask != clact_mask).any()


def test_spec_parse_and_keys():
    assert SparsitySpec.parse("dense").kind == "dense"
    s = SparsitySpec.parse("8:16")
    assert (s.n, s.m) == (8, 16)
    assert s.key == "8_16"
    u = SparsitySpec.parse("u70")
    assert u.kind == "unstructured"
    assert abs(u.keep_frac - 0.3) < 1e-9
    assert u.key == "u70"
    with pytest.raises(Exception):
        SparsitySpec.parse("banana")
