"""Python side of the flat-f32 checkpoint format shared with rust.

Format (see `rust/src/util/tensor.rs`): `<stem>.bin` is a little-endian f32
blob; `<stem>.json` is a manifest `{"tensors": [{name, shape, offset}...]}`.
Rust loads checkpoints/method-params written here; tests in both languages
pin the round-trip.
"""

from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np


def save(stem: str, tensors: Dict[str, np.ndarray]) -> None:
    """Write `<stem>.bin` + `<stem>.json`. Keys are sorted for determinism
    (matching rust's BTreeMap iteration order)."""
    blob = bytearray()
    entries = []
    for name in sorted(tensors.keys()):
        arr = np.asarray(tensors[name], dtype=np.float32)
        # NB: record the shape before ascontiguousarray, which promotes
        # 0-d scalars to 1-d.
        shape = list(arr.shape)
        entries.append({"name": name, "shape": shape, "offset": len(blob)})
        blob.extend(np.ascontiguousarray(arr).tobytes())
    os.makedirs(os.path.dirname(stem) or ".", exist_ok=True)
    with open(stem + ".bin", "wb") as f:
        f.write(bytes(blob))
    with open(stem + ".json", "w") as f:
        json.dump(
            {"tensors": entries, "format": "nmsparse-flat-f32-le-v1"}, f, indent=1
        )


def load(stem: str) -> Dict[str, np.ndarray]:
    """Read tensors back as float32 numpy arrays."""
    with open(stem + ".json") as f:
        manifest = json.load(f)
    with open(stem + ".bin", "rb") as f:
        blob = f.read()
    out: Dict[str, np.ndarray] = {}
    for e in manifest["tensors"]:
        shape = tuple(e["shape"])
        count = int(np.prod(shape)) if shape else 1
        arr = np.frombuffer(
            blob, dtype="<f4", count=count, offset=e["offset"]
        ).reshape(shape)
        out[e["name"]] = arr.copy()
    return out
