"""Golden-vector export: pins the kernel/oracle semantics for the rust side.

Writes `artifacts/golden.json` with deterministic inputs and the oracle's
outputs for the selection/transform primitives; `rust/tests/golden.rs`
replays them through `rust/src/sparsity/` so all three implementations
(Pallas kernel, jnp oracle, rust reference) share one pinned behaviour.
"""

from __future__ import annotations

import json

import numpy as np

from .kernels.ref import SparsitySpec, nm_mask, sparse_linear_ref

import jax.numpy as jnp


def make_golden(seed: int = 20250710) -> dict:
    rng = np.random.default_rng(seed)
    cases = []

    # nm_mask cases (with exact zeros and ties mixed in).
    for n, m, rows in [(2, 4, 3), (4, 8, 2), (8, 16, 2), (16, 32, 1)]:
        x = rng.normal(size=(rows, 2 * m)).astype(np.float32)
        x[x < -1.2] = 0.0
        x[0, :2] = 0.5  # ties
        mask = np.asarray(nm_mask(jnp.abs(jnp.asarray(x)), n, m))
        cases.append(
            {
                "kind": "nm_mask",
                "n": n,
                "m": m,
                "scores_abs": np.abs(x).flatten().tolist(),
                "rows": rows,
                "cols": 2 * m,
                "mask": mask.flatten().astype(int).tolist(),
            }
        )

    # Full mitigated prune pipeline (matches rust mitigated_nm_prune with
    # identity weights: y = f(x)).
    for shift_mode, use_var in [(0.0, 0.0), (1.0, 0.0), (1.0, 1.0), (0.0, 1.0)]:
        l, h = 4, 16
        x = (rng.normal(size=(l, h)) + 2.0).astype(np.float32)
        w = np.eye(h, dtype=np.float32)
        y = np.asarray(
            sparse_linear_ref(
                jnp.asarray(x),
                jnp.asarray(w),
                SparsitySpec.parse("2:4"),
                shift_mode=shift_mode,
                use_var=use_var,
            )
        )
        cases.append(
            {
                "kind": "mitigated_prune_2_4",
                "shift_mode": shift_mode,
                "use_var": use_var,
                "rows": l,
                "cols": h,
                "x": x.flatten().tolist(),
                "y": y.flatten().tolist(),
            }
        )

    return {"seed": seed, "cases": cases}


def write_golden(path: str, seed: int = 20250710) -> None:
    with open(path, "w") as f:
        json.dump(make_golden(seed), f)
