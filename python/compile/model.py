"""L2: the Llama-style transformer whose linear layers route through the
Pallas sparse-linear kernel.

Architecture (matching the seven sparsifiable linear sites the paper
studies): RMSNorm → attention (q/k/v/out projections, RoPE, causal+padding
mask) → RMSNorm → SwiGLU FFN (gate/up/down). Embedding and LM head stay
dense, as in the paper (only linear-layer *inputs* are sparsified).

`forward` returns exactly what the rust eval harness needs from one call:
per-position next-token logprobs (for loglikelihood scoring and perplexity)
and the logits at each sequence's last valid position (for greedy decode).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels.nm_sparse import rsparse_linear, sparse_linear
from .kernels.ref import SparsitySpec, clact_colnorm

# The seven sparsifiable linear sites, in canonical order.
SITES = ("q", "k", "v", "o", "gate", "up", "down")


@dataclass(frozen=True)
class ModelConfig:
    """Model hyperparameters. Defaults give a ~3.6M-param model that trains
    to memorize the SynthLang world in a few hundred CPU steps."""

    vocab: int = 160
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 4
    ffn: int = 512
    rope_base: float = 10000.0
    # AOT-exported eval shapes.
    eval_batch: int = 16
    eval_seq: int = 64

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    def site_in_dim(self, site: str) -> int:
        """Input dimension of each linear site (what gets sparsified)."""
        return self.ffn if site == "down" else self.d_model

    def site_out_dim(self, site: str) -> int:
        return self.ffn if site in ("gate", "up") else self.d_model


def param_names(cfg: ModelConfig) -> List[str]:
    """Checkpoint tensor names, in the sorted order rust iterates them."""
    names = ["embed.w", "final_norm.g", "lm_head.w"]
    for l in range(cfg.n_layers):
        for s in SITES:
            names.append(f"layers.{l}.{s}.w")
        names.append(f"layers.{l}.norm1.g")
        names.append(f"layers.{l}.norm2.g")
    return sorted(names)


def param_shape(cfg: ModelConfig, name: str) -> Tuple[int, ...]:
    if name == "embed.w" or name == "lm_head.w":
        return (cfg.vocab, cfg.d_model)
    if name.endswith("norm.g") or name.endswith("norm1.g") or name.endswith("norm2.g"):
        return (cfg.d_model,)
    # layers.{l}.{site}.w
    site = name.split(".")[2]
    return (cfg.site_out_dim(site), cfg.site_in_dim(site))


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    """Scaled-normal init."""
    key = jax.random.PRNGKey(seed)
    params: Dict[str, jnp.ndarray] = {}
    for name in param_names(cfg):
        key, sub = jax.random.split(key)
        shape = param_shape(cfg, name)
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[-1]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def num_params(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(param_shape(cfg, n)))) for n in param_names(cfg))


# ------------------------------------------------------------------
# Method inputs: the runtime-selectable sparsification parameters.
# ------------------------------------------------------------------


@dataclass
class MethodInputs:
    """Per-site vectors + global flags steering one forward pass.

    For standard variants: eta/cscale/lsw per (layer, site), enable per
    (layer, site), flags (shift_mode, use_clact, use_var). For R-Sparse
    variants: u/v factors per (layer, site) + enable.
    """

    eta: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    cscale: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    lsw: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    enable: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    u: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    v: Dict[Tuple[int, str], jnp.ndarray] = field(default_factory=dict)
    shift_mode: jnp.ndarray | float = 0.0
    use_clact: jnp.ndarray | float = 0.0
    use_var: jnp.ndarray | float = 0.0

    @staticmethod
    def neutral(cfg: ModelConfig, rank: int = 0) -> "MethodInputs":
        """ACT-magnitude pruning everywhere, no transforms (and rank-r
        identity-ish factors when building an R-Sparse variant)."""
        mi = MethodInputs()
        for l in range(cfg.n_layers):
            for s in SITES:
                d = cfg.site_in_dim(s)
                o = cfg.site_out_dim(s)
                mi.eta[(l, s)] = jnp.zeros((d,), jnp.float32)
                mi.cscale[(l, s)] = jnp.ones((d,), jnp.float32)
                mi.lsw[(l, s)] = jnp.ones((d,), jnp.float32)
                mi.enable[(l, s)] = jnp.ones((), jnp.float32)
                if rank:
                    mi.u[(l, s)] = jnp.zeros((o, rank), jnp.float32)
                    mi.v[(l, s)] = jnp.zeros((rank, d), jnp.float32)
        return mi


def rmsnorm(x: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6) * g


def rope(x: jnp.ndarray, base: float) -> jnp.ndarray:
    """Rotary position embedding over [B, T, H, Dh]."""
    b, t, h, dh = x.shape
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(
    cfg: ModelConfig,
    params: Dict[str, jnp.ndarray],
    tokens: jnp.ndarray,  # [B, T] int32
    lens: jnp.ndarray,  # [B] int32
    spec: SparsitySpec,
    method: Optional[MethodInputs] = None,
    *,
    rsparse: bool = False,
    use_kernel: bool = True,
    capture: Optional[Dict[Tuple[int, str], jnp.ndarray]] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Run the model.

    Returns:
      tgt_lp: [B, T] — tgt_lp[b, t] = log p(tokens[b, t+1] | tokens[b, :t+1])
        for t < T-1; the final column is 0.
      last_logits: [B, V] — logits at position lens[b]-1 (next-token
        distribution for greedy decoding).

    `use_kernel=False` routes sparsification through the pure-jnp oracle —
    used by tests to validate the whole network against the kernel path.
    `capture`, when a dict is supplied, records each site's 2-D input
    activations (calibration).
    """
    if method is None:
        method = MethodInputs.neutral(cfg)
    b, t = tokens.shape
    d = cfg.d_model
    x = params["embed.w"][tokens]  # [B, T, D]
    pos = jnp.arange(t)
    valid = (pos[None, :] < lens[:, None]).astype(jnp.float32)  # [B, T]
    valid_flat = valid.reshape(b * t)

    def site_linear(h2d: jnp.ndarray, l: int, s: str) -> jnp.ndarray:
        """Apply one (possibly sparsified) linear site on [B*T, din]."""
        if capture is not None:
            capture[(l, s)] = h2d
        w = params[f"layers.{l}.{s}.w"]
        if spec.kind == "dense":
            return h2d @ w.T
        if rsparse:
            fn = rsparse_linear if use_kernel else _rsparse_ref
            return fn(
                h2d,
                w,
                method.u[(l, s)],
                method.v[(l, s)],
                spec,
                enable=method.enable[(l, s)],
            )
        colnorm = clact_colnorm(h2d, valid_flat)
        fn = sparse_linear if use_kernel else _sparse_ref
        return fn(
            h2d,
            w,
            spec,
            eta=method.eta[(l, s)],
            cscale=method.cscale[(l, s)],
            colnorm=colnorm,
            lsw=method.lsw[(l, s)],
            enable=method.enable[(l, s)],
            shift_mode=method.shift_mode,
            use_clact=method.use_clact,
            use_var=method.use_var,
        )

    # Attention masks: causal AND key-position-valid.
    causal = pos[None, :] <= pos[:, None]  # [T, T] query x key
    key_valid = pos[None, None, :] < lens[:, None, None]  # [B, 1, T]
    attn_mask = causal[None, :, :] & key_valid  # [B, T, T]
    neg = jnp.asarray(-1e9, jnp.float32)

    for l in range(cfg.n_layers):
        h = rmsnorm(x, params[f"layers.{l}.norm1.g"])
        h2d = h.reshape(b * t, d)
        q = site_linear(h2d, l, "q").reshape(b, t, cfg.n_heads, cfg.head_dim)
        k = site_linear(h2d, l, "k").reshape(b, t, cfg.n_heads, cfg.head_dim)
        v = site_linear(h2d, l, "v").reshape(b, t, cfg.n_heads, cfg.head_dim)
        q = rope(q, cfg.rope_base)
        k = rope(k, cfg.rope_base)
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(
            jnp.asarray(cfg.head_dim, jnp.float32)
        )
        scores = jnp.where(attn_mask[:, None, :, :], scores, neg)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", probs, v).reshape(b, t, d)
        o = site_linear(ctx.reshape(b * t, d), l, "o").reshape(b, t, d)
        x = x + o

        h2 = rmsnorm(x, params[f"layers.{l}.norm2.g"])
        h2d2 = h2.reshape(b * t, d)
        g = site_linear(h2d2, l, "gate").reshape(b, t, cfg.ffn)
        u_ = site_linear(h2d2, l, "up").reshape(b, t, cfg.ffn)
        f = jax.nn.silu(g) * u_
        dn = site_linear(f.reshape(b * t, cfg.ffn), l, "down").reshape(b, t, d)
        x = x + dn

    x = rmsnorm(x, params["final_norm.g"])
    logits = x @ params["lm_head.w"].T  # [B, T, V]
    logprobs = jax.nn.log_softmax(logits, axis=-1)

    # Next-token logprobs.
    nxt = tokens[:, 1:]  # [B, T-1]
    lp = jnp.take_along_axis(logprobs[:, :-1, :], nxt[..., None], axis=-1)[..., 0]
    tgt_lp = jnp.concatenate([lp, jnp.zeros((b, 1), jnp.float32)], axis=1)

    # Last valid position's logits.
    last_idx = jnp.clip(lens - 1, 0, t - 1)
    last_logits = jnp.take_along_axis(
        logits, last_idx[:, None, None].astype(jnp.int32), axis=1
    )[:, 0, :]
    return tgt_lp, last_logits


# Oracle-path adapters (signature match with the kernel functions).
def _sparse_ref(h2d, w, spec, **kw):
    from .kernels.ref import sparse_linear_ref

    return sparse_linear_ref(h2d, w, spec, **kw)


def _rsparse_ref(h2d, w, u, v, spec, **kw):
    from .kernels.ref import rsparse_linear_ref

    return rsparse_linear_ref(h2d, w, u, v, spec, **kw)


def lm_loss(
    cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens: jnp.ndarray
) -> jnp.ndarray:
    """Dense next-token cross-entropy over a [B, T] batch (training path —
    never exported; the request path is rust + the eval artifacts)."""
    b, t = tokens.shape
    lens = jnp.full((b,), t, jnp.int32)
    tgt_lp, _ = forward(cfg, params, tokens, lens, SparsitySpec("dense"))
    return -tgt_lp[:, : t - 1].mean()
