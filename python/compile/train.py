"""Build-time training of the SynthLang checkpoint (never on request path).

Trains the dense model with hand-rolled Adam (no optax in the offline
image) on the token stream produced by `nmsparse datagen`, then saves the
checkpoint in the shared flat-f32 format. A few hundred CPU steps suffice:
the corpus is a closed world the 2.7M-param model memorizes.
"""

from __future__ import annotations

import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .model import ModelConfig, init_params, lm_loss


def load_token_stream(path: str) -> np.ndarray:
    """Read a little-endian u32 token file written by `nmsparse datagen`."""
    return np.fromfile(path, dtype="<u4").astype(np.int32)


def batch_iter(stream: np.ndarray, batch: int, seq: int, seed: int):
    """Yield random [batch, seq] windows forever."""
    rng = np.random.default_rng(seed)
    max_start = len(stream) - seq - 1
    assert max_start > 0, "corpus too short for the training sequence length"
    while True:
        starts = rng.integers(0, max_start, size=batch)
        yield np.stack([stream[s : s + seq] for s in starts])


def adam_init(params: Dict[str, jnp.ndarray]):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1**tf
    bc2 = 1 - b2**tf
    new_params = {
        k: params[k] - lr * (m[k] / bc1) / (jnp.sqrt(v[k] / bc2) + eps) for k in params
    }
    return new_params, {"m": m, "v": v, "t": t}


def train(
    cfg: ModelConfig,
    stream: np.ndarray,
    *,
    steps: int = 400,
    batch: int = 32,
    seq: int = 128,
    lr: float = 1e-3,
    seed: int = 0,
    log_every: int = 50,
) -> Tuple[Dict[str, jnp.ndarray], list]:
    """Train and return (params, loss_history[(step, loss)])."""
    params = init_params(cfg, seed)
    opt = adam_init(params)

    @jax.jit
    def step_fn(params, opt, tokens):
        loss, grads = jax.value_and_grad(lambda p: lm_loss(cfg, p, tokens))(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    history = []
    it = batch_iter(stream, batch, seq, seed)
    t0 = time.time()
    for step in range(1, steps + 1):
        tokens = jnp.asarray(next(it))
        params, opt, loss = step_fn(params, opt, tokens)
        if step % log_every == 0 or step == 1 or step == steps:
            loss_f = float(loss)
            history.append((step, loss_f))
            print(
                f"[train] step {step:4d}/{steps} loss {loss_f:.4f} "
                f"({time.time() - t0:.1f}s)",
                flush=True,
            )
    return params, history


def eval_ppl(cfg: ModelConfig, params, stream: np.ndarray, *, seq: int = 128, max_windows: int = 32) -> float:
    """Held-out perplexity over contiguous windows (dense model)."""
    n = min(max_windows, (len(stream) - 1) // seq)
    losses = []
    fn = jax.jit(lambda p, t: lm_loss(cfg, p, t))
    for i in range(n):
        window = stream[i * seq : i * seq + seq][None, :]
        losses.append(float(fn(params, jnp.asarray(window, jnp.int32))))
    return float(np.exp(np.mean(losses)))
