"""AOT pipeline: train → calibrate → lower every HLO variant.

Run once at build time (`make artifacts`); the rust coordinator then serves
everything from `artifacts/` with no python on the request path.

Interchange format is HLO *text* (not serialized HloModuleProto): jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Outputs under --out (default ../artifacts):
  ckpt.bin/.json           trained checkpoint (flat-f32 store)
  methodparams.bin/.json   calibration products (S-PTS/L-PTS/LS/Amber/SVD)
  model_<key>.hlo.txt      one HLO per sparsity-pattern variant
  io_manifest.json         per-variant ordered input lists + config + train log
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import tensorstore
from .calibrate import calibrate_all
from .kernels.ref import SparsitySpec
from .model import (
    SITES,
    MethodInputs,
    ModelConfig,
    forward,
    num_params,
    param_names,
    param_shape,
)
from .train import eval_ppl, load_token_stream, train

# The pattern grid every table draws from.
STANDARD_VARIANTS = ["dense", "2:4", "4:8", "8:16", "16:32", "u20", "u50", "u70", "u90"]
RSPARSE_VARIANTS: List[Tuple[str, int]] = [
    ("2:4", 64),
    ("2:4", 128),
    ("8:16", 64),
    ("8:16", 128),
]


def to_hlo_text(lowered) -> str:
    """Lower jax's stablehlo to XLA HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def method_input_names(cfg: ModelConfig, rsparse: bool, rank: int) -> List[Tuple[str, tuple]]:
    """Ordered (name, shape) list of the method inputs for one variant."""
    entries: List[Tuple[str, tuple]] = []
    for l in range(cfg.n_layers):
        for s in SITES:
            d = cfg.site_in_dim(s)
            o = cfg.site_out_dim(s)
            if rsparse:
                entries.append((f"m.u.l{l}.{s}", (o, rank)))
                entries.append((f"m.v.l{l}.{s}", (rank, d)))
                entries.append((f"m.enable.l{l}.{s}", ()))
            else:
                entries.append((f"m.eta.l{l}.{s}", (d,)))
                entries.append((f"m.cscale.l{l}.{s}", (d,)))
                entries.append((f"m.lsw.l{l}.{s}", (d,)))
                entries.append((f"m.enable.l{l}.{s}", ()))
    if not rsparse:
        entries.append(("m.flag.shift_mode", ()))
        entries.append(("m.flag.use_clact", ()))
        entries.append(("m.flag.use_var", ()))
    return entries


def build_variant_fn(cfg: ModelConfig, spec: SparsitySpec, rsparse: bool, rank: int):
    """A positional-args function `(tokens, lens, *arrays) -> (tgt_lp,
    last_logits)` plus its full ordered input manifest."""
    wnames = param_names(cfg)  # already sorted
    # The dense variant ignores method inputs entirely; jax DCEs unused
    # parameters at lowering, so they must not be declared at all.
    is_dense = spec.kind == "dense" and not rsparse
    mentries = [] if is_dense else method_input_names(cfg, rsparse, rank)

    def fn(tokens, lens, *arrays):
        params = dict(zip(wnames, arrays[: len(wnames)]))
        if is_dense:
            return forward(cfg, params, tokens, lens, spec)
        marrays = arrays[len(wnames) :]
        mi = MethodInputs()
        idx = 0
        for l in range(cfg.n_layers):
            for s in SITES:
                if rsparse:
                    mi.u[(l, s)] = marrays[idx]
                    mi.v[(l, s)] = marrays[idx + 1]
                    mi.enable[(l, s)] = marrays[idx + 2]
                    idx += 3
                else:
                    mi.eta[(l, s)] = marrays[idx]
                    mi.cscale[(l, s)] = marrays[idx + 1]
                    mi.lsw[(l, s)] = marrays[idx + 2]
                    mi.enable[(l, s)] = marrays[idx + 3]
                    idx += 4
        if not rsparse:
            mi.shift_mode = marrays[idx]
            mi.use_clact = marrays[idx + 1]
            mi.use_var = marrays[idx + 2]
        return forward(cfg, params, tokens, lens, spec, mi, rsparse=rsparse)

    inputs = [("tokens", (cfg.eval_batch, cfg.eval_seq), "i32"),
              ("lens", (cfg.eval_batch,), "i32")]
    inputs += [(f"w.{n}", param_shape(cfg, n), "f32") for n in wnames]
    inputs += [(n, shape, "f32") for n, shape in mentries]
    return fn, inputs


def lower_variant(cfg: ModelConfig, key: str, rsparse_rank: int | None) -> Tuple[str, list]:
    """Lower one variant to HLO text; returns (hlo_text, input manifest)."""
    spec = SparsitySpec.parse(key)
    rsparse = rsparse_rank is not None
    fn, inputs = build_variant_fn(cfg, spec, rsparse, rsparse_rank or 0)
    specs = [
        jax.ShapeDtypeStruct(shape, jnp.int32 if dt == "i32" else jnp.float32)
        for _, shape, dt in inputs
    ]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered), [
        {"name": n, "shape": list(shape), "dtype": dt} for n, shape, dt in inputs
    ]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--data", default="../artifacts/data", help="datagen output dir")
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--steps", type=int, default=400, help="training steps")
    ap.add_argument("--lpts-steps", type=int, default=120)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--force", action="store_true", help="retrain + relower everything")
    ap.add_argument("--only", default="", help="comma list of variant keys to lower")
    args = ap.parse_args()

    data, out = args.data, args.out
    os.makedirs(out, exist_ok=True)
    if not os.path.exists(os.path.join(data, "vocab.json")):
        sys.exit(
            f"error: {data}/vocab.json not found — run `cargo run --release "
            "-- datagen` (or `make artifacts`, which orders this correctly)"
        )
    with open(os.path.join(data, "vocab.json")) as f:
        vocab_info = json.load(f)
    cfg = ModelConfig(vocab=int(vocab_info["padded_size"]))
    print(f"[aot] model: {num_params(cfg):,} params, vocab {cfg.vocab}", flush=True)

    manifest: Dict = {
        "config": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "ffn": cfg.ffn,
            "eval_batch": cfg.eval_batch,
            "eval_seq": cfg.eval_seq,
            "num_params": num_params(cfg),
            "sites": list(SITES),
        },
        "variants": {},
    }

    # ---- train (or reuse) ----
    ckpt_stem = os.path.join(out, "ckpt")
    if os.path.exists(ckpt_stem + ".bin") and not args.force:
        print("[aot] reusing existing checkpoint", flush=True)
        params = {k: jnp.asarray(v) for k, v in tensorstore.load(ckpt_stem).items()}
        train_info = json.load(open(os.path.join(out, "train_log.json")))
    else:
        stream = load_token_stream(os.path.join(data, "corpus_train.tokens"))
        t0 = time.time()
        params, history = train(cfg, stream, steps=args.steps, seed=args.seed)
        valid = load_token_stream(os.path.join(data, "corpus_valid.tokens"))
        ppl = eval_ppl(cfg, params, valid)
        train_info = {
            "steps": args.steps,
            "final_loss": history[-1][1],
            "valid_ppl": ppl,
            "history": history,
            "train_seconds": round(time.time() - t0, 1),
        }
        print(f"[aot] trained: loss {history[-1][1]:.4f}, valid ppl {ppl:.3f}", flush=True)
        tensorstore.save(ckpt_stem, {k: np.asarray(v) for k, v in params.items()})
        json.dump(train_info, open(os.path.join(out, "train_log.json"), "w"), indent=1)
    manifest["train"] = {k: train_info[k] for k in ("steps", "final_loss", "valid_ppl")}

    # ---- calibrate (or reuse) ----
    mp_stem = os.path.join(out, "methodparams")
    if os.path.exists(mp_stem + ".bin") and not args.force:
        print("[aot] reusing existing methodparams", flush=True)
    else:
        calib = load_token_stream(os.path.join(data, "corpus_calib.tokens"))
        mp = calibrate_all(
            cfg, params, calib, lpts_steps=args.lpts_steps, seed=args.seed,
            batch=cfg.eval_batch, seq=cfg.eval_seq,
        )
        tensorstore.save(mp_stem, mp)
        print(f"[aot] methodparams: {len(mp)} tensors", flush=True)

    # ---- lower variants ----
    only = set(k for k in args.only.split(",") if k)
    jobs: List[Tuple[str, str, int | None]] = []
    for key in STANDARD_VARIANTS:
        jobs.append((SparsitySpec.parse(key).key, key, None))
    for key, rank in RSPARSE_VARIANTS:
        jobs.append((f"rsparse{rank}_{SparsitySpec.parse(key).key}", key, rank))

    for file_key, pattern_key, rank in jobs:
        if only and file_key not in only:
            continue
        path = os.path.join(out, f"model_{file_key}.hlo.txt")
        if os.path.exists(path) and not args.force:
            # Still need the manifest entry: re-derive the input list cheaply.
            _, inputs = build_variant_fn(
                ModelConfig(vocab=cfg.vocab), SparsitySpec.parse(pattern_key),
                rank is not None, rank or 0,
            )
            manifest["variants"][file_key] = {
                "file": os.path.basename(path),
                "pattern": pattern_key,
                "rank": rank,
                "inputs": [
                    {"name": n, "shape": list(s), "dtype": d} for n, s, d in inputs
                ],
            }
            print(f"[aot] kept existing {path}", flush=True)
            continue
        t0 = time.time()
        hlo, inputs = lower_variant(cfg, pattern_key, rank)
        with open(path, "w") as f:
            f.write(hlo)
        manifest["variants"][file_key] = {
            "file": os.path.basename(path),
            "pattern": pattern_key,
            "rank": rank,
            "inputs": inputs,
        }
        print(
            f"[aot] lowered {file_key:16s} -> {os.path.basename(path)} "
            f"({len(hlo)/1e6:.1f} MB, {time.time()-t0:.1f}s)",
            flush=True,
        )

    with open(os.path.join(out, "io_manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote io_manifest.json with {len(manifest['variants'])} variants")

    # Golden vectors pinning the selection/transform semantics for rust.
    from .golden import write_golden

    write_golden(os.path.join(out, "golden.json"))
    print("[aot] wrote golden.json")


if __name__ == "__main__":
    main()
