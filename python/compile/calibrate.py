"""Calibration: the method parameters that need data or weights.

Produces everything Table 1's "calibration required" column lists, using the
calibration split (WikiText-2's role):

  * S-PTS   — per-channel activation means per site (collected, fixed).
  * Amber   — channel norms of outlier-clipped standardized weights
              (weights-only, no data).
  * L-PTS   — per-channel shifts *learned* per site by minimizing local
              output reconstruction under the target pattern.
  * LS      — learnable diagonal scale (Table 5), learned jointly with
              L-PTS shifts.
  * R-Sparse — rank-r truncated-SVD factors of each weight matrix.

All results are saved as one flat-f32 store (`methodparams.*`) keyed by
`<kind>.<pattern>.l<layer>.<site>` where applicable.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.ref import SparsitySpec, sparse_linear_ref
from .model import SITES, MethodInputs, ModelConfig, forward


def capture_activations(
    cfg: ModelConfig,
    params,
    tokens: np.ndarray,
    lens: np.ndarray,
) -> Dict[Tuple[int, str], np.ndarray]:
    """Run the dense model over calibration batches, recording each linear
    site's 2-D input activations (valid rows only)."""
    captures: Dict[Tuple[int, str], list] = {}
    b, t = tokens.shape[1], tokens.shape[2]
    for bi in range(tokens.shape[0]):
        cap: Dict[Tuple[int, str], jnp.ndarray] = {}
        forward(
            cfg,
            params,
            jnp.asarray(tokens[bi]),
            jnp.asarray(lens[bi]),
            SparsitySpec("dense"),
            capture=cap,
        )
        valid = (np.arange(t)[None, :] < lens[bi][:, None]).reshape(b * t)
        for key, arr in cap.items():
            captures.setdefault(key, []).append(np.asarray(arr)[valid])
    return {k: np.concatenate(v, axis=0) for k, v in captures.items()}


def spts_etas(acts: Dict[Tuple[int, str], np.ndarray]) -> Dict[str, np.ndarray]:
    """S-PTS: per-channel mean of each site's calibration activations."""
    return {
        f"spts_eta.l{l}.{s}": acts[(l, s)].mean(axis=0).astype(np.float32)
        for (l, s) in acts
    }


def amber_cscales(cfg: ModelConfig, params) -> Dict[str, np.ndarray]:
    """Amber-Pruner channel norms from weights (port of
    rust `sparsity::criteria::amber_channel_norms`)."""
    out = {}
    for l in range(cfg.n_layers):
        for s in SITES:
            w = np.asarray(params[f"layers.{l}.{s}.w"])
            flat = np.sort(w, axis=None)
            lo = flat[int(len(flat) * 0.005)]
            hi = flat[min(int(len(flat) * 0.995), len(flat) - 1)]
            clipped = np.clip(w, lo, hi)
            z = (clipped - clipped.mean()) / max(clipped.std(), 1e-8)
            out[f"amber_cscale.l{l}.{s}"] = np.sqrt(
                (z**2).sum(axis=0)
            ).astype(np.float32)
    return out


def learn_pts(
    cfg: ModelConfig,
    params,
    acts: Dict[Tuple[int, str], np.ndarray],
    spec: SparsitySpec,
    *,
    learn_scale: bool,
    steps: int = 120,
    lr: float = 0.05,
    sample_rows: int = 512,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """L-PTS (and optionally LS): per-site gradient descent on the local
    reconstruction loss || sparse_linear(x; eta, ls) - x @ W^T ||^2.

    The keep-mask is piecewise-constant in eta so gradients flow through
    the value path only — the same trick QAT uses for quantizer params.
    """
    rng = np.random.default_rng(seed)
    out: Dict[str, np.ndarray] = {}
    pat = spec.key

    for (l, s), x_all in acts.items():
        w = params[f"layers.{l}.{s}.w"]
        rows = min(sample_rows, x_all.shape[0])
        idx = rng.choice(x_all.shape[0], size=rows, replace=False)
        x = jnp.asarray(x_all[idx])
        y_ref = x @ w.T

        def loss_fn(eta, ls):
            y = sparse_linear_ref(
                x,
                w,
                spec,
                eta=eta,
                lsw=ls if learn_scale else jnp.ones_like(ls),
                shift_mode=2.0,
            )
            return jnp.mean((y - y_ref) ** 2)

        grad_fn = jax.jit(jax.value_and_grad(loss_fn, argnums=(0, 1)))
        eta = jnp.asarray(x_all.mean(axis=0))  # warm-start at S-PTS
        ls = jnp.ones((x.shape[1],), jnp.float32)
        # Plain SGD with decay — robust for this convex-ish local problem.
        for step in range(steps):
            _, (ge, gl) = grad_fn(eta, ls)
            cur_lr = lr * (0.5 ** (step // 40))
            eta = eta - cur_lr * ge
            if learn_scale:
                ls = ls - cur_lr * gl
        out[f"lpts_eta.{pat}.l{l}.{s}"] = np.asarray(eta, dtype=np.float32)
        if learn_scale:
            out[f"ls_scale.{pat}.l{l}.{s}"] = np.asarray(ls, dtype=np.float32)
    return out


def rsparse_factors(cfg: ModelConfig, params, ranks=(64, 128)) -> Dict[str, np.ndarray]:
    """Rank-r truncated SVD of each site weight: W ~= U V with
    U=[out,r], V=[r,in]."""
    out = {}
    for l in range(cfg.n_layers):
        for s in SITES:
            w = np.asarray(params[f"layers.{l}.{s}.w"])
            uu, ss, vv = np.linalg.svd(w, full_matrices=False)
            for r in ranks:
                rr = min(r, len(ss))
                u = (uu[:, :rr] * ss[:rr]).astype(np.float32)
                v = vv[:rr].astype(np.float32)
                if rr < r:  # pad so every site has uniform [out,r]/[r,in]
                    u = np.pad(u, ((0, 0), (0, r - rr)))
                    v = np.pad(v, ((0, r - rr), (0, 0)))
                out[f"rsparse{r}_u.l{l}.{s}"] = u
                out[f"rsparse{r}_v.l{l}.{s}"] = v
    return out


def calibrate_all(
    cfg: ModelConfig,
    params,
    calib_tokens: np.ndarray,
    *,
    batches: int = 4,
    batch: int = 16,
    seq: int = 64,
    lpts_steps: int = 120,
    seed: int = 0,
) -> Dict[str, np.ndarray]:
    """Run the full calibration pipeline; returns the methodparams dict."""
    # Chop the calibration stream into [batches, batch, seq] full windows.
    need = batches * batch * seq
    assert len(calib_tokens) >= need, "calibration split too small"
    toks = calib_tokens[:need].reshape(batches, batch, seq).astype(np.int32)
    lens = np.full((batches, batch), seq, np.int32)

    print("[calibrate] capturing activations...", flush=True)
    acts = capture_activations(cfg, params, toks, lens)

    out: Dict[str, np.ndarray] = {}
    out.update(spts_etas(acts))
    out.update(amber_cscales(cfg, params))
    for pat in ("2:4", "8:16"):
        print(f"[calibrate] learning L-PTS for {pat}...", flush=True)
        out.update(
            learn_pts(
                cfg, params, acts, SparsitySpec.parse(pat),
                learn_scale=False, steps=lpts_steps, seed=seed,
            )
        )
        print(f"[calibrate] learning LS+L-PTS for {pat}...", flush=True)
        ls = learn_pts(
            cfg, params, acts, SparsitySpec.parse(pat),
            learn_scale=True, steps=lpts_steps, seed=seed + 1,
        )
        # learn_pts with scale emits both eta and scale under lpts/ls keys;
        # rename the eta to the ls_eta family to keep both variants.
        for k, v in ls.items():
            if k.startswith("lpts_eta."):
                out[k.replace("lpts_eta.", "ls_eta.")] = v
            else:
                out[k] = v
    print("[calibrate] computing R-Sparse SVD factors...", flush=True)
    out.update(rsparse_factors(cfg, params))
    return out
