"""L1: the Pallas N:M activation-sparsification kernel.

One fused kernel performs the whole pre-matmul pipeline on a tile of token
rows held in VMEM — shift, score, exact-N:M (or per-row top-k) selection,
learnable diagonal scale, shift compensation, per-token variance correction
— followed by the ``x @ w.T`` matmul on the MXU. No gather/scatter: masks
are applied multiplicatively, keeping the MXU-friendly dense layout; the
compressed-metadata story lives in the rust `metadata`/`hwmodel` modules.

TPU adaptation of the paper's (GPU-oriented) setting — see DESIGN.md
§Hardware-Adaptation:
  * selection is rank-by-pairwise-comparison: O(M^2) vectorized compares on
    the VPU, no data-dependent control flow, no sort network;
  * BlockSpec streams ``[TILE_R, H]`` activation tiles and the full
    ``[OUT, H]`` weight tile HBM→VMEM; per-token statistics never leave
    VMEM;
  * ``interpret=True`` everywhere: the CPU PJRT plugin cannot execute
    Mosaic custom-calls, so the kernel lowers to plain HLO. Structure (tile
    shapes, footprints) is what we optimize; wallclock on real TPUs is
    estimated analytically in EXPERIMENTS.md §Perf.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import EPS, SparsitySpec

# Tile height (token rows per grid step).
#
# TPU-shaped tiling is 64 rows (64 x 1024 ch x 4 B = 256 KiB activation tile,
# comfortably inside a 16 MiB VMEM budget next to the weight tile — see
# hwmodel::KernelTileEstimate). For the CPU-interpret artifacts we default to
# tile_r=None => one grid step covering all rows: interpret-mode pallas_call
# lowers its grid to a serialized scan whose per-step slicing costs ~5x the
# kernel body on CPU (EXPERIMENTS.md §Perf: 15.7ms -> 2.96ms per site call).
# Real-TPU lowering would keep TPU_TILE_R.
TPU_TILE_R = 64
DEFAULT_TILE_R = None


def _select_mask(score: jnp.ndarray, spec: SparsitySpec) -> jnp.ndarray:
    """Keep-mask for a [tile_r, h] score tile. Same rank rule as ref.py."""
    tile_r, h = score.shape
    if spec.kind == "nm":
        n, m = spec.n, spec.m
        s = score.reshape(tile_r, h // m, m)
        si = s[..., :, None]
        sj = s[..., None, :]
        gt = (sj > si).sum(axis=-1)
        j_idx = jnp.arange(m)[None, :]
        i_idx = jnp.arange(m)[:, None]
        tie = ((sj == si) & (j_idx < i_idx)).sum(axis=-1)
        mask = ((gt + tie) < n).astype(score.dtype)
        return mask.reshape(tile_r, h)
    # Unstructured per-row top-k: shared bisection threshold (see ref.py —
    # same function, so kernel == oracle exactly; avoids XLA's slow CPU
    # sort and maps to vectorized compares on the TPU VPU).
    from .ref import topk_row_mask

    return topk_row_mask(score, spec.keep_frac)


def _sparse_linear_kernel(
    x_ref,
    w_ref,
    eta_ref,
    cscale_ref,
    colnorm_ref,
    lsw_ref,
    flags_ref,
    o_ref,
    *,
    spec: SparsitySpec,
):
    """Pallas kernel body for one [TILE_R, H] tile.

    flags layout (f32[4]): [enable, shift_mode, use_clact, use_var].
    """
    x = x_ref[...]  # [tile_r, h]
    w = w_ref[...]  # [out, h]
    eta = eta_ref[...]  # [h]
    cscale = cscale_ref[...]  # [h]
    colnorm = colnorm_ref[...]  # [h]
    lsw = lsw_ref[...]  # [h]
    flags = flags_ref[...]  # [4]
    enable, shift_mode, use_clact, use_var = flags[0], flags[1], flags[2], flags[3]

    # --- shift ---
    row_mean = x.mean(axis=-1, keepdims=True)
    eta_eff = jnp.where(
        shift_mode == 1.0,
        jnp.broadcast_to(row_mean, x.shape),
        jnp.where(shift_mode == 2.0, jnp.broadcast_to(eta, x.shape), 0.0),
    )
    xs = x - eta_eff

    # --- score ---
    scale_eff = jnp.where(use_clact == 1.0, colnorm, cscale)
    score = jnp.abs(xs) * scale_eff

    # --- select ---
    mask = _select_mask(score, spec)

    # --- apply + compensate + variance-correct ---
    xp = xs * mask * lsw
    xc = xp + eta_eff
    var_x = x.var(axis=-1, keepdims=True)
    var_c = xc.var(axis=-1, keepdims=True)
    nu = jnp.sqrt(var_x / jnp.maximum(var_c, EPS))
    nu = jnp.where(var_c <= EPS, 1.0, nu)
    xf = jnp.where(use_var == 1.0, nu * xc, xc)
    xout = jnp.where(enable >= 0.5, xf, x)

    # --- matmul on the MXU ---
    o_ref[...] = jnp.dot(xout, w.T, preferred_element_type=jnp.float32)


def sparse_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: SparsitySpec,
    *,
    eta: Optional[jnp.ndarray] = None,
    cscale: Optional[jnp.ndarray] = None,
    colnorm: Optional[jnp.ndarray] = None,
    lsw: Optional[jnp.ndarray] = None,
    enable: jnp.ndarray | float = 1.0,
    shift_mode: jnp.ndarray | float = 0.0,
    use_clact: jnp.ndarray | float = 0.0,
    use_var: jnp.ndarray | float = 0.0,
    tile_r: int | None = DEFAULT_TILE_R,
    interpret: bool = True,
) -> jnp.ndarray:
    """Sparse linear ``y[r, out] = f(x)[r, h] @ w[out, h].T`` via Pallas.

    Method parameters are runtime tensors so a single lowered HLO serves
    every (criterion x transform) combination of its pattern; see DESIGN.md
    "Artifact/variant scheme". ``tile_r=None`` = single-tile grid (the CPU
    default, see above).
    """
    rows, h = x.shape
    out = w.shape[0]
    assert w.shape[1] == h, f"w {w.shape} incompatible with x {x.shape}"

    if spec.kind == "dense":
        return x @ w.T
    if tile_r is None:
        tile_r = rows

    if eta is None:
        eta = jnp.zeros((h,), x.dtype)
    if cscale is None:
        cscale = jnp.ones((h,), x.dtype)
    if colnorm is None:
        colnorm = jnp.ones((h,), x.dtype)
    if lsw is None:
        lsw = jnp.ones((h,), x.dtype)
    flags = jnp.stack(
        [
            jnp.asarray(enable, x.dtype),
            jnp.asarray(shift_mode, x.dtype),
            jnp.asarray(use_clact, x.dtype),
            jnp.asarray(use_var, x.dtype),
        ]
    )

    tile_r = min(tile_r, rows)
    # Pad rows to a tile multiple; padded rows are sliced off after.
    pad = (-rows) % tile_r
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, h), x.dtype)], axis=0)
    grid = (x.shape[0] // tile_r,)

    kernel = functools.partial(_sparse_linear_kernel, spec=spec)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, h), lambda i: (i, 0)),
            pl.BlockSpec((out, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_r, out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], out), x.dtype),
        interpret=interpret,
    )(x, w, eta, cscale, colnorm, lsw, flags)
    return y[:rows]


def rsparse_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    v: jnp.ndarray,
    spec: SparsitySpec,
    *,
    enable: jnp.ndarray | float = 1.0,
    tile_r: int | None = DEFAULT_TILE_R,
    interpret: bool = True,
) -> jnp.ndarray:
    """R-Sparse fused kernel: ``sigma(x) @ w.T + (x - sigma(x)) @ (u v).T``.

    The low-rank residual path contracts through rank r first, so the extra
    FLOPs are ~r/out of the main matmul.
    """
    rows, h = x.shape
    out = w.shape[0]
    r = u.shape[1]
    assert v.shape == (r, h), f"v {v.shape} != ({r}, {h})"
    if spec.kind == "dense":
        return x @ w.T
    if tile_r is None:
        tile_r = rows

    enable_arr = jnp.reshape(jnp.asarray(enable, x.dtype), (1,))
    tile_r = min(tile_r, rows)
    pad = (-rows) % tile_r
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, h), x.dtype)], axis=0)
    grid = (x.shape[0] // tile_r,)

    def kernel(x_ref, w_ref, u_ref, v_ref, en_ref, o_ref):
        xt = x_ref[...]
        wt = w_ref[...]
        ut = u_ref[...]
        vt = v_ref[...]
        en = en_ref[...][0]
        mask = _select_mask(jnp.abs(xt), spec)
        xp = xt * mask
        resid = xt - xp
        y = jnp.dot(xp, wt.T, preferred_element_type=jnp.float32) + jnp.dot(
            jnp.dot(resid, vt.T, preferred_element_type=jnp.float32),
            ut.T,
            preferred_element_type=jnp.float32,
        )
        y_dense = jnp.dot(xt, wt.T, preferred_element_type=jnp.float32)
        o_ref[...] = jnp.where(en >= 0.5, y, y_dense)

    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_r, h), lambda i: (i, 0)),
            pl.BlockSpec((out, h), lambda i: (0, 0)),
            pl.BlockSpec((out, r), lambda i: (0, 0)),
            pl.BlockSpec((r, h), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_r, out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], out), x.dtype),
        interpret=interpret,
    )(x, w, u, v, enable_arr)
    return y[:rows]
