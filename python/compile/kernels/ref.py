"""Pure-jnp oracle for the sparse-linear operator.

This module defines the *semantics* of N:M / unstructured activation
sparsification with the paper's selection criteria and error-mitigation
transforms. The Pallas kernel (`nm_sparse.py`) must match it to float
tolerance — `python/tests/test_kernel.py` sweeps shapes, patterns and flag
combinations with hypothesis. The rust-side reference
(`rust/src/sparsity/`) pins the same behaviour via golden vectors.

Selection-rank rule (shared everywhere): within a block, element i is kept
iff ``#{j: s_j > s_i} + #{j < i: s_j == s_i} < N`` — exact-N selection with
ties resolved toward lower indices.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

EPS = 1e-12


@dataclass(frozen=True)
class SparsitySpec:
    """Static sparsification configuration baked into one HLO variant.

    kind: "dense" | "nm" | "unstructured"
    n, m: block parameters for kind == "nm"
    keep_frac: kept fraction for kind == "unstructured"
    """

    kind: str = "dense"
    n: int = 0
    m: int = 0
    keep_frac: float = 1.0

    @staticmethod
    def parse(s: str) -> "SparsitySpec":
        s = s.strip().lower()
        if s in ("dense", "orig"):
            return SparsitySpec("dense")
        if s.startswith("u"):
            sparsity = int(s[1:])
            return SparsitySpec("unstructured", keep_frac=1.0 - sparsity / 100.0)
        n, m = s.split(":")
        return SparsitySpec("nm", n=int(n), m=int(m))

    @property
    def key(self) -> str:
        if self.kind == "dense":
            return "dense"
        if self.kind == "nm":
            return f"{self.n}_{self.m}"
        return f"u{round((1.0 - self.keep_frac) * 100)}"


def nm_mask(scores: jnp.ndarray, n: int, m: int) -> jnp.ndarray:
    """Exact-N keep mask per M-block along the last axis (float 0/1).

    O(M^2) pairwise-compare ranking: branch-free, no sort — the form the
    Pallas kernel uses on the VPU.
    """
    *lead, h = scores.shape
    assert h % m == 0, f"hidden dim {h} not a multiple of M={m}"
    s = scores.reshape(*lead, h // m, m)
    si = s[..., :, None]  # i axis
    sj = s[..., None, :]  # j axis
    gt = (sj > si).sum(axis=-1)
    j_idx = jnp.arange(m)[None, :]
    i_idx = jnp.arange(m)[:, None]
    tie = ((sj == si) & (j_idx < i_idx)).sum(axis=-1)
    rank = gt + tie
    mask = (rank < n).astype(scores.dtype)
    return mask.reshape(*lead, h)


def topk_row_mask(scores: jnp.ndarray, keep_frac: float, iters: int = 30) -> jnp.ndarray:
    """Per-row top-k mask via bisection on the threshold value.

    Converges to the k-th order statistic: the returned mask keeps every
    element >= the threshold (ties at the threshold are all kept, exactly
    like a sort-based top-k with >=). Bisection is O(iters * h) instead of
    O(h log h) sort — and, crucially, lowers to cheap vectorized compares
    instead of XLA's slow CPU sort (~13x faster at h=512; §Perf). The
    kernel uses this same function so kernel == oracle bit-for-bit.
    """
    import jax

    h = scores.shape[-1]
    k = int(round(h * keep_frac))
    if k >= h:
        return jnp.ones_like(scores)
    if k <= 0:
        return jnp.zeros_like(scores)
    lo = jnp.zeros(scores.shape[:-1] + (1,), scores.dtype)
    hi = scores.max(axis=-1, keepdims=True) + jnp.asarray(1e-6, scores.dtype)

    def body(_, lh):
        lo, hi = lh
        mid = 0.5 * (lo + hi)
        c = (scores >= mid).sum(axis=-1, keepdims=True)
        take = c >= k
        return jnp.where(take, mid, lo), jnp.where(take, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return (scores >= lo).astype(scores.dtype)


def clact_colnorm(x: jnp.ndarray, valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """CLACT column-energy term sqrt(sum_p x_pj^2) over valid rows.

    Within a per-row block the row-norm denominator of eq. (4) is constant,
    so CLACT ordering == |x| * colnorm ordering; we therefore implement
    CLACT as a dynamic per-channel score scale.
    """
    x2 = x * x
    if valid is not None:
        x2 = x2 * valid[..., None]
    return jnp.sqrt(x2.sum(axis=tuple(range(x.ndim - 1))) + EPS)


def sparse_linear_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: SparsitySpec,
    *,
    eta: Optional[jnp.ndarray] = None,
    cscale: Optional[jnp.ndarray] = None,
    lsw: Optional[jnp.ndarray] = None,
    enable: jnp.ndarray | float = 1.0,
    shift_mode: jnp.ndarray | float = 0.0,
    use_var: jnp.ndarray | float = 0.0,
    use_clact: jnp.ndarray | float = 0.0,
    colnorm: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Reference sparse linear: ``y = f(x) @ w.T`` with
    ``f`` = shift → score → select → diag-scale → compensate → VAR.

    Args:
      x: ``[rows, h]`` activations.
      w: ``[out, h]`` weights.
      spec: static pattern.
      eta: ``[h]`` static shift vector (S-PTS / L-PTS), used when
        ``shift_mode == 2``.
      cscale: ``[h]`` static per-channel score scale (ones = ACT,
        Amber norms = Amber-Pruner).
      lsw: ``[h]`` learnable diagonal scale (LS); ones = off.
      enable: 0/1 — bypass sparsification entirely when 0 (layer subsets).
      shift_mode: 0 none, 1 dynamic per-token mean (D-PTS), 2 use ``eta``.
      use_var: 0/1 — per-token variance correction after compensation.
      use_clact: 0/1 — override score scale with the dynamic CLACT column
        energies (``colnorm``).
      colnorm: ``[h]`` CLACT column energies (precomputed by the caller over
        the valid rows of the full sequence).
    """
    if spec.kind == "dense":
        return x @ w.T

    h = x.shape[-1]
    if eta is None:
        eta = jnp.zeros((h,), x.dtype)
    if cscale is None:
        cscale = jnp.ones((h,), x.dtype)
    if lsw is None:
        lsw = jnp.ones((h,), x.dtype)
    if colnorm is None:
        colnorm = jnp.ones((h,), x.dtype)
    shift_mode = jnp.asarray(shift_mode, x.dtype)
    use_var = jnp.asarray(use_var, x.dtype)
    use_clact = jnp.asarray(use_clact, x.dtype)
    enable = jnp.asarray(enable, x.dtype)

    row_mean = x.mean(axis=-1, keepdims=True)
    eta_eff = jnp.where(
        shift_mode == 1.0,
        jnp.broadcast_to(row_mean, x.shape),
        jnp.where(shift_mode == 2.0, jnp.broadcast_to(eta, x.shape), 0.0),
    )
    xs = x - eta_eff

    scale_eff = jnp.where(use_clact == 1.0, colnorm, cscale)
    score = jnp.abs(xs) * scale_eff

    if spec.kind == "nm":
        mask = nm_mask(score, spec.n, spec.m)
    else:
        mask = topk_row_mask(score, spec.keep_frac)

    xp = xs * mask * lsw
    xc = xp + eta_eff

    var_x = x.var(axis=-1, keepdims=True)
    var_c = xc.var(axis=-1, keepdims=True)
    nu = jnp.sqrt(var_x / jnp.maximum(var_c, EPS))
    nu = jnp.where(var_c <= EPS, 1.0, nu)
    xf = jnp.where(use_var == 1.0, nu * xc, xc)

    xout = jnp.where(enable >= 0.5, xf, x)
    return xout @ w.T


def rsparse_linear_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    v: jnp.ndarray,
    spec: SparsitySpec,
    *,
    enable: jnp.ndarray | float = 1.0,
) -> jnp.ndarray:
    """R-Sparse reference (Appendix B):
    ``Y = sigma(X) W^T + (X - sigma(X)) (U V)^T`` with sigma = magnitude
    N:M selection and ``U V`` the rank-r SVD approximation of ``W``.
    ``u: [out, r]``, ``v: [r, h]``.
    """
    if spec.kind == "dense":
        return x @ w.T
    score = jnp.abs(x)
    if spec.kind == "nm":
        mask = nm_mask(score, spec.n, spec.m)
    else:
        mask = topk_row_mask(score, spec.keep_frac)
    xp = x * mask
    resid = x - xp
    y = xp @ w.T + (resid @ v.T) @ u.T
    enable = jnp.asarray(enable, x.dtype)
    y_dense = x @ w.T
    return jnp.where(enable >= 0.5, y, y_dense)
